//! Batched prediction server — the L3 serving path: a dedicated model
//! thread owns the engine (PJRT handles are per-thread) and drains an
//! mpsc queue with **dynamic batching**: it collects up to `max_batch`
//! requests (waiting at most `max_wait` for stragglers), stacks them into
//! one row-block, runs a single blocked predict, and fans the results
//! back out. Clients hold a cheap, cloneable, `Send` [`Handle`].
//!
//! [`MulticlassServer`] is the one-vs-all counterpart: a batch of rows is
//! served by **one** multi-output predict (`Engine::predict_multi`), so
//! the kernel panels are amortized across the batch rows *and* the K
//! classes — a K-class request costs one panel sweep, not K
//! (DESIGN.md §Perf "Multi-RHS path").
//!
//! [`predict_source`] is the **offline bulk** path: it streams a chunked
//! [`crate::data::DataSource`] through the model, so scoring a dataset
//! larger than RAM keeps only one chunk of features resident
//! (DESIGN.md § "Out-of-core path").

use crate::data::source::DataSource;
use crate::falkon::{FalkonModel, FalkonMulticlass};
use crate::linalg::mat::Mat;
use crate::util::fault::FaultError;
use anyhow::{anyhow, Result};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

/// Result of one offline bulk-scoring sweep over a [`DataSource`].
#[derive(Debug, Clone)]
pub struct BulkScore {
    /// model predictions (with the target offset applied), in row order
    pub preds: Vec<f64>,
    /// the targets streamed alongside (for evaluation)
    pub targets: Vec<f64>,
    pub rows: usize,
    /// largest resident chunk (feature bytes) during the sweep — the
    /// out-of-core serving path's peak-RSS proxy
    pub max_chunk_bytes: usize,
    /// non-finite rows dropped by a skip-policy sanitizer upstream
    /// ([`crate::data::SanitizeSource`]); 0 on clean or fail-fast streams
    pub skipped_rows: usize,
}

/// Offline batch serving from a chunked source: sweep the stream once,
/// scoring each resident chunk with the blocked predict path, so a
/// dataset larger than RAM is served with O(chunk) feature memory. The
/// online counterpart is [`Server`] (request batching); this is the bulk
/// path behind `falkon predict` on `.shard` inputs.
pub fn predict_source(
    model: &FalkonModel,
    engine: &crate::runtime::Engine,
    source: &mut dyn DataSource,
) -> Result<BulkScore> {
    anyhow::ensure!(
        source.d() == model.centers.cols,
        "source d {} != model d {}",
        source.d(),
        model.centers.cols
    );
    let retry = engine.opts().retry;
    retry.run("bulk predict: reset", || source.reset())?;
    let mut preds = Vec::new();
    let mut targets = Vec::new();
    let mut max_chunk_bytes = 0usize;
    while let Some(chunk) = retry.run("bulk predict: next_chunk", || source.next_chunk())? {
        anyhow::ensure!(chunk.start == preds.len(), "source chunks must be contiguous");
        max_chunk_bytes = max_chunk_bytes.max(chunk.x_bytes());
        // dtype-aware per-chunk dispatch: f32 chunks stay f32 through the
        // kernel panels (f64-accumulated), f64 chunks take the exact path
        let mut p = model.predict_block(engine, &chunk.x)?;
        preds.append(&mut p);
        targets.extend_from_slice(&chunk.y);
    }
    let rows = preds.len();
    Ok(BulkScore {
        preds,
        targets,
        rows,
        max_chunk_bytes,
        skipped_rows: source.skipped_rows(),
    })
}

#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// engine name ("xla", "xla-jnp", "rust") — constructed on the server
    /// thread because PJRT clients are thread-local
    pub engine: String,
    /// rust-engine worker threads for the blocked predict path. Only
    /// batches larger than one kernel tile (128 rows) fan out, so this
    /// matters when `max_batch` is raised above the default 64.
    pub workers: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            engine: "xla".into(),
            workers: 1,
        }
    }
}

struct Request {
    features: Vec<f64>,
    reply: Sender<Result<f64>>,
}

/// Client handle: send features, block on the prediction.
#[derive(Clone)]
pub struct Handle {
    tx: Sender<Request>,
    d: usize,
}

impl Handle {
    pub fn predict(&self, features: Vec<f64>) -> Result<f64> {
        if features.len() != self.d {
            return Err(anyhow!(
                "feature dim {} != model dim {}",
                features.len(),
                self.d
            ));
        }
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Request {
                features,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("server stopped"))?;
        reply_rx.recv().map_err(|_| anyhow!("server dropped request"))?
    }
}

/// Server statistics snapshot.
#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    /// mean rows per executed batch
    pub mean_batch: f64,
}

pub struct Server {
    handle: Handle,
    join: Option<std::thread::JoinHandle<ServeStats>>,
    shutdown: Sender<()>,
}

impl Server {
    /// Spawn the model thread and return (server, client handle).
    pub fn start(model: FalkonModel, cfg: ServeConfig) -> Result<Server> {
        let d = model.centers.cols;
        let (tx, rx) = channel::<Request>();
        let (stop_tx, stop_rx) = channel::<()>();
        let join = std::thread::Builder::new()
            .name("falkon-serve".into())
            .spawn(move || serve_loop(model, cfg, rx, stop_rx))
            .map_err(|e| anyhow!("spawning server: {e}"))?;
        Ok(Server {
            handle: Handle { tx, d },
            join: Some(join),
            shutdown: stop_tx,
        })
    }

    pub fn handle(&self) -> Handle {
        self.handle.clone()
    }

    /// Stop the server and collect stats.
    pub fn stop(mut self) -> ServeStats {
        let _ = self.shutdown.send(());
        // drop our handle so the queue closes once clients are done
        match self.join.take() {
            Some(join) => join.join().unwrap_or_default(),
            None => ServeStats::default(),
        }
    }
}

/// Best-effort human-readable payload of a caught panic.
fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".into()
    }
}

fn serve_loop(
    model: FalkonModel,
    cfg: ServeConfig,
    rx: Receiver<Request>,
    stop: Receiver<()>,
) -> ServeStats {
    // engine lives on this thread (PJRT client is thread-local)
    let engine = match crate::runtime::Engine::by_name(&cfg.engine, cfg.workers) {
        Ok(e) => e,
        Err(err) => {
            eprintln!("serve: engine init failed ({err}); falling back to rust engine");
            crate::runtime::Engine::rust_with(crate::runtime::EngineOptions {
                workers: cfg.workers,
                ..Default::default()
            })
        }
    };
    let d = model.centers.cols;
    let mut stats = ServeStats::default();
    let mut pending: Vec<Request> = Vec::new();

    loop {
        if stop.try_recv().is_ok() {
            break;
        }
        // block for the first request of a batch
        if pending.is_empty() {
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(r) => pending.push(r),
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // then gather stragglers up to max_batch / max_wait
        let deadline = Instant::now() + cfg.max_wait;
        while pending.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(_) => break,
            }
        }
        // validate per request before stacking: [`Handle::predict`]
        // already checks dims, but the queue is a public boundary — a
        // malformed request must get a typed error back, not panic the
        // copy below and take the whole serve thread with it
        let mut batch: Vec<Request> = Vec::with_capacity(pending.len());
        for r in pending.drain(..) {
            if r.features.len() == d {
                batch.push(r);
            } else {
                let _ = r.reply.send(Err(FaultError::fatal(format!(
                    "feature dim {} != model dim {d}",
                    r.features.len()
                ))));
            }
        }
        if batch.is_empty() {
            continue;
        }
        // run the batch; a panic inside the predict path fails this batch,
        // not the server
        let rows = batch.len();
        let mut x = Mat::zeros(rows, d);
        for (i, r) in batch.iter().enumerate() {
            x.row_mut(i).copy_from_slice(&r.features);
        }
        let preds = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            model.predict(&engine, &x)
        }))
        .unwrap_or_else(|p| Err(anyhow!("prediction panicked: {}", panic_msg(p.as_ref()))));
        match preds {
            Ok(p) => {
                for (i, r) in batch.drain(..).enumerate() {
                    let _ = r.reply.send(Ok(p[i]));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for r in batch.drain(..) {
                    let _ = r.reply.send(Err(anyhow!("{msg}")));
                }
            }
        }
        stats.requests += rows as u64;
        stats.batches += 1;
    }
    if stats.batches > 0 {
        stats.mean_batch = stats.requests as f64 / stats.batches as f64;
    }
    stats
}

// ---------------------------------------------------------------------
// multiclass serving
// ---------------------------------------------------------------------

/// One multiclass answer: the argmax class plus the per-class scores
/// (callers needing calibrated probabilities can post-process the scores).
#[derive(Debug, Clone)]
pub struct ClassPrediction {
    pub class: usize,
    pub scores: Vec<f64>,
}

struct ClassRequest {
    features: Vec<f64>,
    reply: Sender<Result<ClassPrediction>>,
}

/// Client handle for the multiclass server.
#[derive(Clone)]
pub struct MulticlassHandle {
    tx: Sender<ClassRequest>,
    d: usize,
}

impl MulticlassHandle {
    pub fn predict(&self, features: Vec<f64>) -> Result<ClassPrediction> {
        if features.len() != self.d {
            return Err(anyhow!(
                "feature dim {} != model dim {}",
                features.len(),
                self.d
            ));
        }
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(ClassRequest {
                features,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("server stopped"))?;
        reply_rx.recv().map_err(|_| anyhow!("server dropped request"))?
    }
}

/// Batched one-vs-all server: same dynamic-batching loop as [`Server`],
/// but each executed batch runs a single multi-output predict covering
/// every class.
pub struct MulticlassServer {
    handle: MulticlassHandle,
    join: Option<std::thread::JoinHandle<ServeStats>>,
    shutdown: Sender<()>,
}

impl MulticlassServer {
    /// Spawn the model thread and return the server (handles via
    /// [`MulticlassServer::handle`]).
    pub fn start(model: FalkonMulticlass, cfg: ServeConfig) -> Result<MulticlassServer> {
        let d = model.centers.cols;
        let (tx, rx) = channel::<ClassRequest>();
        let (stop_tx, stop_rx) = channel::<()>();
        let join = std::thread::Builder::new()
            .name("falkon-serve-mc".into())
            .spawn(move || serve_multiclass_loop(model, cfg, rx, stop_rx))
            .map_err(|e| anyhow!("spawning multiclass server: {e}"))?;
        Ok(MulticlassServer {
            handle: MulticlassHandle { tx, d },
            join: Some(join),
            shutdown: stop_tx,
        })
    }

    pub fn handle(&self) -> MulticlassHandle {
        self.handle.clone()
    }

    /// Stop the server and collect stats (the serve loop notices the stop
    /// signal on its next idle poll).
    pub fn stop(mut self) -> ServeStats {
        let _ = self.shutdown.send(());
        match self.join.take() {
            Some(join) => join.join().unwrap_or_default(),
            None => ServeStats::default(),
        }
    }
}

fn serve_multiclass_loop(
    model: FalkonMulticlass,
    cfg: ServeConfig,
    rx: Receiver<ClassRequest>,
    stop: Receiver<()>,
) -> ServeStats {
    let engine = match crate::runtime::Engine::by_name(&cfg.engine, cfg.workers) {
        Ok(e) => e,
        Err(err) => {
            eprintln!("serve: engine init failed ({err}); falling back to rust engine");
            crate::runtime::Engine::rust_with(crate::runtime::EngineOptions {
                workers: cfg.workers,
                ..Default::default()
            })
        }
    };
    let d = model.centers.cols;
    // stacked once: the per-batch predict reads the same M×K block
    let alphas = model.alphas_mat();
    let mut stats = ServeStats::default();
    let mut pending: Vec<ClassRequest> = Vec::new();

    loop {
        if stop.try_recv().is_ok() {
            break;
        }
        if pending.is_empty() {
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(r) => pending.push(r),
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        let deadline = Instant::now() + cfg.max_wait;
        while pending.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(_) => break,
            }
        }
        // same public-boundary validation as the regression loop: typed
        // error per malformed request, never a panic in the copy below
        let mut batch: Vec<ClassRequest> = Vec::with_capacity(pending.len());
        for r in pending.drain(..) {
            if r.features.len() == d {
                batch.push(r);
            } else {
                let _ = r.reply.send(Err(FaultError::fatal(format!(
                    "feature dim {} != model dim {d}",
                    r.features.len()
                ))));
            }
        }
        if batch.is_empty() {
            continue;
        }
        let rows = batch.len();
        let mut x = Mat::zeros(rows, d);
        for (i, r) in batch.iter().enumerate() {
            x.row_mut(i).copy_from_slice(&r.features);
        }
        // one panel-amortized predict for the whole (rows × K) batch; a
        // panic fails the batch, not the server
        let scores = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.predict_multi(
                model.config.kernel,
                &x,
                &model.centers,
                &alphas,
                model.config.sigma,
            )
        }))
        .unwrap_or_else(|p| Err(anyhow!("prediction panicked: {}", panic_msg(p.as_ref()))));
        match scores {
            Ok(sm) => {
                for (i, r) in batch.drain(..).enumerate() {
                    let row = sm.row(i);
                    // total_cmp: a pathological request whose scores go NaN
                    // must not panic the serve thread for everyone else
                    let class = (0..row.len())
                        .max_by(|&a, &b| row[a].total_cmp(&row[b]))
                        .unwrap_or(0);
                    let _ = r.reply.send(Ok(ClassPrediction {
                        class,
                        scores: row.to_vec(),
                    }));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for r in batch.drain(..) {
                    let _ = r.reply.send(Err(anyhow!("{msg}")));
                }
            }
        }
        stats.requests += rows as u64;
        stats.batches += 1;
    }
    if stats.batches > 0 {
        stats.mean_batch = stats.requests as f64 / stats.batches as f64;
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::falkon::FalkonConfig;
    use crate::runtime::Engine;
    use crate::util::rng::Rng;

    fn tiny_model() -> (FalkonModel, Mat, Vec<f64>) {
        let mut rng = Rng::new(1);
        let data = synth::smooth_regression(&mut rng, 300, 4, 0.05);
        let eng = Engine::rust();
        let cfg = FalkonConfig {
            sigma: 1.5,
            lam: 1e-4,
            m: 32,
            t: 12,
            ..Default::default()
        };
        let model = crate::falkon::fit(&eng, &data.x, &data.y, &cfg).unwrap();
        (model, data.x, data.y)
    }

    #[test]
    fn serves_correct_predictions() {
        let (model, x, _) = tiny_model();
        let eng = Engine::rust();
        let want = model.predict(&eng, &x.slice_rows(0, 10)).unwrap();
        let server = Server::start(
            model,
            ServeConfig {
                engine: "rust".into(),
                ..Default::default()
            },
        )
        .unwrap();
        let h = server.handle();
        for i in 0..10 {
            let got = h.predict(x.row(i).to_vec()).unwrap();
            assert!((got - want[i]).abs() < 1e-12, "{got} vs {}", want[i]);
        }
        let stats = server.stop();
        assert_eq!(stats.requests, 10);
    }

    #[test]
    fn batches_concurrent_clients() {
        let (model, x, _) = tiny_model();
        let server = Server::start(
            model,
            ServeConfig {
                engine: "rust".into(),
                max_batch: 16,
                max_wait: Duration::from_millis(10),
                ..Default::default()
            },
        )
        .unwrap();
        let h = server.handle();
        let results: Vec<f64> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..32)
                .map(|i| {
                    let h = h.clone();
                    let row = x.row(i % x.rows).to_vec();
                    s.spawn(move || h.predict(row).unwrap())
                })
                .collect();
            handles.into_iter().map(|j| j.join().unwrap()).collect()
        });
        assert_eq!(results.len(), 32);
        let stats = server.stop();
        assert_eq!(stats.requests, 32);
        // dynamic batching must have coalesced at least some requests
        assert!(stats.batches < 32, "batches {}", stats.batches);
        assert!(stats.mean_batch > 1.0);
    }

    fn tiny_multiclass() -> (crate::falkon::FalkonMulticlass, Mat, Vec<usize>) {
        let mut rng = Rng::new(21);
        let (n, d, k) = (400, 4, 3);
        let data = crate::data::synth::blobs(&mut rng, n, d, k);
        let eng = Engine::rust();
        let cfg = FalkonConfig {
            sigma: 4.0,
            lam: 1e-5,
            m: 40,
            t: 10,
            seed: 3,
            ..Default::default()
        };
        let model = crate::falkon::fit_multiclass(&eng, &data, &cfg).unwrap();
        let labels = data.labels.clone().unwrap();
        (model, data.x, labels)
    }

    #[test]
    fn multiclass_server_matches_direct_predict() {
        let (model, x, _) = tiny_multiclass();
        let eng = Engine::rust();
        let want_classes = model.predict_class(&eng, &x.slice_rows(0, 12)).unwrap();
        let want_scores = model.scores_mat(&eng, &x.slice_rows(0, 12)).unwrap();
        let server = MulticlassServer::start(
            model,
            ServeConfig {
                engine: "rust".into(),
                ..Default::default()
            },
        )
        .unwrap();
        let h = server.handle();
        for i in 0..12 {
            let got = h.predict(x.row(i).to_vec()).unwrap();
            assert_eq!(got.class, want_classes[i], "row {i}");
            assert_eq!(got.scores.len(), want_scores.cols);
            for kc in 0..want_scores.cols {
                assert!(
                    (got.scores[kc] - want_scores[(i, kc)]).abs() < 1e-12,
                    "row {i} class {kc}"
                );
            }
        }
        let stats = server.stop();
        assert_eq!(stats.requests, 12);
    }

    #[test]
    fn multiclass_server_batches_concurrent_clients() {
        let (model, x, _) = tiny_multiclass();
        let server = MulticlassServer::start(
            model,
            ServeConfig {
                engine: "rust".into(),
                max_batch: 16,
                max_wait: Duration::from_millis(10),
                ..Default::default()
            },
        )
        .unwrap();
        let h = server.handle();
        let results: Vec<ClassPrediction> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..24)
                .map(|i| {
                    let h = h.clone();
                    let row = x.row(i % x.rows).to_vec();
                    s.spawn(move || h.predict(row).unwrap())
                })
                .collect();
            handles.into_iter().map(|j| j.join().unwrap()).collect()
        });
        assert_eq!(results.len(), 24);
        let stats = server.stop();
        assert_eq!(stats.requests, 24);
        assert!(stats.batches < 24, "batches {}", stats.batches);
    }

    #[test]
    fn multiclass_server_rejects_wrong_dimension() {
        let (model, _, _) = tiny_multiclass();
        let server = MulticlassServer::start(
            model,
            ServeConfig {
                engine: "rust".into(),
                ..Default::default()
            },
        )
        .unwrap();
        let h = server.handle();
        assert!(h.predict(vec![1.0]).is_err());
        server.stop();
    }

    #[test]
    fn rejects_wrong_dimension() {
        let (model, _, _) = tiny_model();
        let server = Server::start(
            model,
            ServeConfig {
                engine: "rust".into(),
                ..Default::default()
            },
        )
        .unwrap();
        let h = server.handle();
        assert!(h.predict(vec![1.0, 2.0]).is_err());
        server.stop();
    }

    #[test]
    fn serve_loop_survives_malformed_queue_request() {
        // bypass Handle::predict's client-side dim check and push a
        // malformed request straight into the queue: the serve loop must
        // reply with a typed error and keep serving everyone else
        let (model, x, _) = tiny_model();
        let eng = Engine::rust();
        let want = model.predict(&eng, &x.slice_rows(0, 1)).unwrap()[0];
        let server = Server::start(
            model,
            ServeConfig {
                engine: "rust".into(),
                ..Default::default()
            },
        )
        .unwrap();
        let h = server.handle();
        let (reply_tx, reply_rx) = channel();
        h.tx.send(Request {
            features: vec![1.0],
            reply: reply_tx,
        })
        .unwrap();
        let err = reply_rx.recv().unwrap().unwrap_err();
        assert!(format!("{err:#}").contains("feature dim"), "{err:#}");
        let got = h.predict(x.row(0).to_vec()).unwrap();
        assert!((got - want).abs() < 1e-12, "server must still serve");
        server.stop();
    }

    #[test]
    fn multiclass_serve_loop_survives_malformed_queue_request() {
        let (model, x, _) = tiny_multiclass();
        let server = MulticlassServer::start(
            model,
            ServeConfig {
                engine: "rust".into(),
                ..Default::default()
            },
        )
        .unwrap();
        let h = server.handle();
        let (reply_tx, reply_rx) = channel();
        h.tx.send(ClassRequest {
            features: vec![0.5, 0.5],
            reply: reply_tx,
        })
        .unwrap();
        let err = reply_rx.recv().unwrap().unwrap_err();
        assert!(format!("{err:#}").contains("feature dim"), "{err:#}");
        let got = h.predict(x.row(0).to_vec()).unwrap();
        assert!(got.class < 3, "server must still serve");
        server.stop();
    }

    #[test]
    fn bulk_predict_source_matches_in_memory_predict() {
        let (model, x, y) = tiny_model();
        let eng = Engine::rust();
        let want = model.predict(&eng, &x).unwrap();
        let data = crate::data::Dataset::new_regression("bulk", x, y.clone());
        let mut src = crate::data::MemSource::new(data, 77);
        let score = predict_source(&model, &eng, &mut src).unwrap();
        assert_eq!(score.preds, want);
        assert_eq!(score.targets, y);
        assert_eq!(score.rows, want.len());
        assert_eq!(score.skipped_rows, 0);
        // only one 77-row chunk of features was ever resident
        assert_eq!(score.max_chunk_bytes, 77 * model.centers.cols * 8);
        // dimension mismatch is rejected up front
        let bad = crate::data::Dataset::new_regression(
            "bad",
            Mat::zeros(4, model.centers.cols + 1),
            vec![0.0; 4],
        );
        let mut bad_src = crate::data::MemSource::new(bad, 4);
        assert!(predict_source(&model, &eng, &mut bad_src).is_err());
    }

    #[test]
    fn bulk_predict_f32_source_halves_resident_bytes_within_model() {
        use crate::kernels::tol;
        use crate::linalg::mat32::{Dtype, MatF32};
        let (model, x, y) = tiny_model();
        let eng = Engine::rust();
        // oracle: f64 predict on the rounded-and-widened features, so the
        // comparison isolates the compute tier from storage rounding
        let xr = MatF32::from_mat(&x);
        let want = model.predict(&eng, &xr.to_mat()).unwrap();
        let bound = tol::predict_bound(
            model.config.kernel,
            &xr,
            &MatF32::from_mat(&model.centers),
            &model.alpha,
        );
        let data = crate::data::Dataset::new_regression("bulk32", x, y.clone());
        let mut src = crate::data::MemSource::with_dtype(data, 77, Dtype::F32);
        let score = predict_source(&model, &eng, &mut src).unwrap();
        assert_eq!(score.targets, y);
        assert_eq!(score.rows, want.len());
        for (i, (&got, &w)) in score.preds.iter().zip(&want).enumerate() {
            assert!((got - w).abs() <= bound, "row {i}: {got} vs {w} (bound {bound:.3e})");
        }
        // the peak-chunk proxy must report 4 bytes/element, not 8
        assert_eq!(score.max_chunk_bytes, 77 * model.centers.cols * 4);
    }
}

//! Serving subsystem — the L3 request path.
//!
//! Three front ends share one admission batcher (the private `batch`
//! module):
//!
//! - [`Server`] / [`MulticlassServer`]: in-process channel servers — a
//!   dedicated model thread owns the engine (PJRT handles are
//!   per-thread) and drains an mpsc queue with **dynamic batching**:
//!   up to `max_batch` rows are collected (waiting at most `max_wait`
//!   for stragglers), stacked into one row-block, and served by a
//!   single blocked predict. Clients hold a cheap, cloneable, `Send`
//!   [`Handle`].
//! - [`net::NetServer`]: the network front door — a TCP server speaking
//!   a small length-prefixed binary protocol, admission-batching
//!   requests **across connections** into the same panel-sized sweeps,
//!   and serving multiple named models from a [`registry::ModelRegistry`]
//!   with atomic hot swap.
//! - [`predict_source`]: the **offline bulk** path — streams a chunked
//!   [`crate::data::DataSource`] through the model, so scoring a
//!   dataset larger than RAM keeps only one chunk of features resident
//!   (DESIGN.md § "Out-of-core path").
//!
//! Multiclass requests are served by **one** multi-output predict
//! (`Engine::predict_multi`), so kernel panels are amortized across the
//! batch rows *and* the K classes — a K-class request costs one panel
//! sweep, not K (DESIGN.md §Perf "Multi-RHS path").

mod batch;
pub mod net;
pub mod registry;

use crate::data::source::DataSource;
use crate::falkon::{FalkonModel, FalkonMulticlass};
use crate::runtime::Engine;
use anyhow::{anyhow, Result};
use batch::{run_model_worker, RowsReply, RowsRequest, StatsCell};
use registry::{ModelSlot, ServedModel};
use std::fmt;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::Duration;

/// Result of one offline bulk-scoring sweep over a [`DataSource`].
#[derive(Debug, Clone)]
pub struct BulkScore {
    /// model predictions (with the target offset applied), in row order
    pub preds: Vec<f64>,
    /// the targets streamed alongside (for evaluation)
    pub targets: Vec<f64>,
    pub rows: usize,
    /// largest resident chunk (feature bytes) during the sweep — the
    /// out-of-core serving path's peak-RSS proxy
    pub max_chunk_bytes: usize,
    /// non-finite rows dropped by a skip-policy sanitizer upstream
    /// ([`crate::data::SanitizeSource`]); 0 on clean or fail-fast streams
    pub skipped_rows: usize,
}

/// Offline batch serving from a chunked source: sweep the stream once,
/// scoring each resident chunk with the blocked predict path, so a
/// dataset larger than RAM is served with O(chunk) feature memory. The
/// online counterpart is [`Server`] (request batching); this is the bulk
/// path behind `falkon predict` on `.shard` inputs and the network
/// server's score-shard op.
pub fn predict_source(
    model: &FalkonModel,
    engine: &crate::runtime::Engine,
    source: &mut dyn DataSource,
) -> Result<BulkScore> {
    anyhow::ensure!(
        source.d() == model.centers.cols,
        "source d {} != model d {}",
        source.d(),
        model.centers.cols
    );
    let retry = engine.opts().retry;
    retry.run("bulk predict: reset", || source.reset())?;
    let mut preds = Vec::new();
    let mut targets = Vec::new();
    let mut max_chunk_bytes = 0usize;
    while let Some(chunk) = retry.run("bulk predict: next_chunk", || source.next_chunk())? {
        anyhow::ensure!(chunk.start == preds.len(), "source chunks must be contiguous");
        max_chunk_bytes = max_chunk_bytes.max(chunk.x_bytes());
        // dtype-aware per-chunk dispatch: f32 chunks stay f32 through the
        // kernel panels (f64-accumulated), f64 chunks take the exact path
        let mut p = model.predict_block(engine, &chunk.x)?;
        preds.append(&mut p);
        targets.extend_from_slice(&chunk.y);
    }
    let rows = preds.len();
    Ok(BulkScore {
        preds,
        targets,
        rows,
        max_chunk_bytes,
        skipped_rows: source.skipped_rows(),
    })
}

#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// admission budget in **rows** per executed batch: single-row
    /// requests count 1, a network batch request counts its row count
    pub max_batch: usize,
    /// how long to linger for stragglers after a batch's first request
    pub max_wait: Duration,
    /// engine name ("xla", "xla-jnp", "rust") — constructed on the server
    /// thread because PJRT clients are thread-local. Defaults to the
    /// engine compiled into this binary ([`Engine::default_name`]), so a
    /// default server never pays a doomed engine-init + fallback.
    pub engine: String,
    /// rust-engine worker threads for the blocked predict path. Only
    /// batches larger than one kernel tile (128 rows) fan out, so this
    /// matters when `max_batch` is raised above the default 64.
    pub workers: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            engine: Engine::default_name().into(),
            workers: 1,
        }
    }
}

/// Server statistics snapshot.
#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    /// every dequeued request — answered or rejected, nothing uncounted
    pub requests: u64,
    /// requests answered with a typed error at the queue boundary
    /// (malformed shape); they never reach a predict sweep
    pub rejected: u64,
    /// executed predict sweeps
    pub batches: u64,
    /// total rows through executed sweeps
    pub rows: u64,
    /// mean rows per executed batch (`rows / batches`)
    pub mean_batch: f64,
    /// times a configured engine failed to init and the worker degraded
    /// to the rust engine (see [`ServeEvent::EngineFallback`])
    pub engine_fallbacks: u64,
}

/// Typed events on the serving path: conditions that must not kill a
/// server but must not be silent either. Logged as `[serve] {event}`.
#[derive(Debug, Clone)]
pub enum ServeEvent {
    /// the configured engine failed to construct; the worker serves on
    /// the fallback instead (counted in [`ServeStats::engine_fallbacks`])
    EngineFallback {
        requested: String,
        fallback: String,
        error: String,
    },
    /// a registry slot atomically replaced its model
    ModelSwapped { model: String, generation: u64 },
}

impl fmt::Display for ServeEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeEvent::EngineFallback { requested, fallback, error } => write!(
                f,
                "engine fallback: {requested:?} unavailable ({error}); serving on {fallback:?}"
            ),
            ServeEvent::ModelSwapped { model, generation } => {
                write!(f, "model {model:?} hot-swapped (generation {generation})")
            }
        }
    }
}

/// Client handle: send features, block on the prediction.
#[derive(Clone)]
pub struct Handle {
    tx: Sender<RowsRequest>,
    d: usize,
}

impl Handle {
    pub fn predict(&self, features: Vec<f64>) -> Result<f64> {
        if features.len() != self.d {
            return Err(anyhow!(
                "feature dim {} != model dim {}",
                features.len(),
                self.d
            ));
        }
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(RowsRequest {
                x: features,
                rows: 1,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("server stopped"))?;
        match reply_rx.recv().map_err(|_| anyhow!("server dropped request"))?? {
            RowsReply::Scalars(p) => p
                .first()
                .copied()
                .ok_or_else(|| anyhow!("server returned an empty reply")),
            RowsReply::Classes(_) => Err(anyhow!("multiclass reply on a regression handle")),
        }
    }
}

/// In-process batched prediction server (regression). The network
/// counterpart is [`net::NetServer`]; both run the same admission
/// batcher and model-worker loop.
pub struct Server {
    handle: Handle,
    join: std::thread::JoinHandle<ServeStats>,
    shutdown: Sender<()>,
}

impl Server {
    /// Spawn the model thread and return the server (client handles via
    /// [`Server::handle`]).
    pub fn start(model: FalkonModel, cfg: ServeConfig) -> Result<Server> {
        let d = model.centers.cols;
        let slot = Arc::new(ModelSlot::new(ServedModel::Regression(model)));
        let (tx, rx) = channel::<RowsRequest>();
        let (stop_tx, stop_rx) = channel::<()>();
        let stats = Arc::new(StatsCell::default());
        let join = std::thread::Builder::new()
            .name("falkon-serve".into())
            .spawn(move || run_model_worker(slot, cfg, rx, stop_rx, stats))
            .map_err(|e| anyhow!("spawning server: {e}"))?;
        Ok(Server {
            handle: Handle { tx, d },
            join,
            shutdown: stop_tx,
        })
    }

    pub fn handle(&self) -> Handle {
        self.handle.clone()
    }

    /// Stop the server and collect stats. The server's own queue sender
    /// is dropped **before** joining, so with no cloned client handles
    /// outstanding the worker exits immediately on channel disconnect —
    /// a first-class shutdown path, not a poll race. With live clones
    /// the explicit stop signal is honored at the next idle poll
    /// (≤ 20 ms), so `stop()` still returns promptly.
    pub fn stop(self) -> ServeStats {
        let Server { handle, join, shutdown } = self;
        drop(handle);
        let _ = shutdown.send(());
        join.join().unwrap_or_default()
    }
}

// ---------------------------------------------------------------------
// multiclass serving
// ---------------------------------------------------------------------

/// One multiclass answer: the argmax class plus the per-class scores
/// (callers needing calibrated probabilities can post-process the scores).
#[derive(Debug, Clone)]
pub struct ClassPrediction {
    pub class: usize,
    pub scores: Vec<f64>,
}

/// Client handle for the multiclass server.
#[derive(Clone)]
pub struct MulticlassHandle {
    tx: Sender<RowsRequest>,
    d: usize,
}

impl MulticlassHandle {
    pub fn predict(&self, features: Vec<f64>) -> Result<ClassPrediction> {
        if features.len() != self.d {
            return Err(anyhow!(
                "feature dim {} != model dim {}",
                features.len(),
                self.d
            ));
        }
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(RowsRequest {
                x: features,
                rows: 1,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("server stopped"))?;
        match reply_rx.recv().map_err(|_| anyhow!("server dropped request"))?? {
            RowsReply::Classes(p) => p
                .into_iter()
                .next()
                .ok_or_else(|| anyhow!("server returned an empty reply")),
            RowsReply::Scalars(_) => Err(anyhow!("regression reply on a multiclass handle")),
        }
    }
}

/// Batched one-vs-all server: same admission batcher as [`Server`], but
/// each executed batch runs a single multi-output predict covering
/// every class.
pub struct MulticlassServer {
    handle: MulticlassHandle,
    join: std::thread::JoinHandle<ServeStats>,
    shutdown: Sender<()>,
}

impl MulticlassServer {
    /// Spawn the model thread and return the server (handles via
    /// [`MulticlassServer::handle`]).
    pub fn start(model: FalkonMulticlass, cfg: ServeConfig) -> Result<MulticlassServer> {
        let d = model.centers.cols;
        let slot = Arc::new(ModelSlot::new(ServedModel::Multiclass(model)));
        let (tx, rx) = channel::<RowsRequest>();
        let (stop_tx, stop_rx) = channel::<()>();
        let stats = Arc::new(StatsCell::default());
        let join = std::thread::Builder::new()
            .name("falkon-serve-mc".into())
            .spawn(move || run_model_worker(slot, cfg, rx, stop_rx, stats))
            .map_err(|e| anyhow!("spawning multiclass server: {e}"))?;
        Ok(MulticlassServer {
            handle: MulticlassHandle { tx, d },
            join,
            shutdown: stop_tx,
        })
    }

    pub fn handle(&self) -> MulticlassHandle {
        self.handle.clone()
    }

    /// Stop the server and collect stats (same shutdown contract as
    /// [`Server::stop`]).
    pub fn stop(self) -> ServeStats {
        let MulticlassServer { handle, join, shutdown } = self;
        drop(handle);
        let _ = shutdown.send(());
        join.join().unwrap_or_default()
    }
}

/// Best-effort human-readable payload of a caught panic.
pub(crate) fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::falkon::FalkonConfig;
    use crate::linalg::mat::Mat;
    use crate::util::rng::Rng;
    use std::time::Instant;

    fn tiny_model() -> (FalkonModel, Mat, Vec<f64>) {
        let mut rng = Rng::new(1);
        let data = synth::smooth_regression(&mut rng, 300, 4, 0.05);
        let eng = Engine::rust();
        let cfg = FalkonConfig {
            sigma: 1.5,
            lam: 1e-4,
            m: 32,
            t: 12,
            ..Default::default()
        };
        let model = crate::falkon::fit(&eng, &data.x, &data.y, &cfg).unwrap();
        (model, data.x, data.y)
    }

    #[test]
    fn default_engine_is_the_compiled_in_one() {
        // a default server must not pay a doomed engine init: the
        // default engine name always constructs on this build
        let cfg = ServeConfig::default();
        assert_eq!(cfg.engine, Engine::default_name());
        assert!(Engine::by_name(&cfg.engine, 1).is_ok());
    }

    #[test]
    fn engine_fallback_is_typed_and_counted() {
        let (model, x, _) = tiny_model();
        let eng = Engine::rust();
        let want = model.predict(&eng, &x.slice_rows(0, 1)).unwrap()[0];
        let server = Server::start(
            model,
            ServeConfig {
                engine: "no-such-engine".into(),
                ..Default::default()
            },
        )
        .unwrap();
        let h = server.handle();
        let got = h.predict(x.row(0).to_vec()).unwrap();
        assert!((got - want).abs() < 1e-12, "fallback engine must serve");
        let stats = server.stop();
        assert_eq!(stats.engine_fallbacks, 1);
    }

    #[test]
    fn serves_correct_predictions() {
        let (model, x, _) = tiny_model();
        let eng = Engine::rust();
        let want = model.predict(&eng, &x.slice_rows(0, 10)).unwrap();
        let server = Server::start(
            model,
            ServeConfig {
                engine: "rust".into(),
                ..Default::default()
            },
        )
        .unwrap();
        let h = server.handle();
        for i in 0..10 {
            let got = h.predict(x.row(i).to_vec()).unwrap();
            assert!((got - want[i]).abs() < 1e-12, "{got} vs {}", want[i]);
        }
        let stats = server.stop();
        assert_eq!(stats.requests, 10);
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.rows, 10);
    }

    #[test]
    fn batches_concurrent_clients() {
        let (model, x, _) = tiny_model();
        let server = Server::start(
            model,
            ServeConfig {
                engine: "rust".into(),
                max_batch: 16,
                max_wait: Duration::from_millis(10),
                ..Default::default()
            },
        )
        .unwrap();
        let h = server.handle();
        let results: Vec<f64> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..32)
                .map(|i| {
                    let h = h.clone();
                    let row = x.row(i % x.rows).to_vec();
                    s.spawn(move || h.predict(row).unwrap())
                })
                .collect();
            handles.into_iter().map(|j| j.join().unwrap()).collect()
        });
        assert_eq!(results.len(), 32);
        let stats = server.stop();
        assert_eq!(stats.requests, 32);
        // dynamic batching must have coalesced at least some requests
        assert!(stats.batches < 32, "batches {}", stats.batches);
        assert!(stats.mean_batch > 1.0);
    }

    #[test]
    fn stop_returns_promptly_with_live_cloned_handle() {
        // regression: stop() used to keep its own queue sender alive
        // through the join, so shutdown leaned on the idle-poll timeout
        // instead of channel disconnect. With a cloned client handle
        // still outstanding the stop signal must be honored promptly.
        let (model, x, _) = tiny_model();
        let server = Server::start(
            model,
            ServeConfig {
                engine: "rust".into(),
                ..Default::default()
            },
        )
        .unwrap();
        let h = server.handle();
        let _ = h.predict(x.row(0).to_vec()).unwrap();
        let t = Instant::now();
        let stats = server.stop();
        assert!(
            t.elapsed() < Duration::from_secs(2),
            "stop() blocked for {:?} with a live clone outstanding",
            t.elapsed()
        );
        assert_eq!(stats.requests, 1);
        // the outstanding clone now reports a stopped server
        assert!(h.predict(x.row(0).to_vec()).is_err());
    }

    fn tiny_multiclass() -> (crate::falkon::FalkonMulticlass, Mat, Vec<usize>) {
        let mut rng = Rng::new(21);
        let (n, d, k) = (400, 4, 3);
        let data = crate::data::synth::blobs(&mut rng, n, d, k);
        let eng = Engine::rust();
        let cfg = FalkonConfig {
            sigma: 4.0,
            lam: 1e-5,
            m: 40,
            t: 10,
            seed: 3,
            ..Default::default()
        };
        let model = crate::falkon::fit_multiclass(&eng, &data, &cfg).unwrap();
        let labels = data.labels.clone().unwrap();
        (model, data.x, labels)
    }

    #[test]
    fn multiclass_server_matches_direct_predict() {
        let (model, x, _) = tiny_multiclass();
        let eng = Engine::rust();
        let want_classes = model.predict_class(&eng, &x.slice_rows(0, 12)).unwrap();
        let want_scores = model.scores_mat(&eng, &x.slice_rows(0, 12)).unwrap();
        let server = MulticlassServer::start(
            model,
            ServeConfig {
                engine: "rust".into(),
                ..Default::default()
            },
        )
        .unwrap();
        let h = server.handle();
        for i in 0..12 {
            let got = h.predict(x.row(i).to_vec()).unwrap();
            assert_eq!(got.class, want_classes[i], "row {i}");
            assert_eq!(got.scores.len(), want_scores.cols);
            for kc in 0..want_scores.cols {
                assert!(
                    (got.scores[kc] - want_scores[(i, kc)]).abs() < 1e-12,
                    "row {i} class {kc}"
                );
            }
        }
        let stats = server.stop();
        assert_eq!(stats.requests, 12);
    }

    #[test]
    fn multiclass_server_batches_concurrent_clients() {
        let (model, x, _) = tiny_multiclass();
        let server = MulticlassServer::start(
            model,
            ServeConfig {
                engine: "rust".into(),
                max_batch: 16,
                max_wait: Duration::from_millis(10),
                ..Default::default()
            },
        )
        .unwrap();
        let h = server.handle();
        let results: Vec<ClassPrediction> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..24)
                .map(|i| {
                    let h = h.clone();
                    let row = x.row(i % x.rows).to_vec();
                    s.spawn(move || h.predict(row).unwrap())
                })
                .collect();
            handles.into_iter().map(|j| j.join().unwrap()).collect()
        });
        assert_eq!(results.len(), 24);
        let stats = server.stop();
        assert_eq!(stats.requests, 24);
        assert!(stats.batches < 24, "batches {}", stats.batches);
    }

    #[test]
    fn multiclass_server_rejects_wrong_dimension() {
        let (model, _, _) = tiny_multiclass();
        let server = MulticlassServer::start(
            model,
            ServeConfig {
                engine: "rust".into(),
                ..Default::default()
            },
        )
        .unwrap();
        let h = server.handle();
        assert!(h.predict(vec![1.0]).is_err());
        server.stop();
    }

    #[test]
    fn rejects_wrong_dimension() {
        let (model, _, _) = tiny_model();
        let server = Server::start(
            model,
            ServeConfig {
                engine: "rust".into(),
                ..Default::default()
            },
        )
        .unwrap();
        let h = server.handle();
        assert!(h.predict(vec![1.0, 2.0]).is_err());
        server.stop();
    }

    #[test]
    fn serve_loop_survives_malformed_queue_request_and_counts_it() {
        // bypass Handle::predict's client-side dim check and push a
        // malformed request straight into the queue: the serve loop must
        // reply with a typed error, keep serving everyone else, and the
        // stats must count the rejected request instead of losing it
        let (model, x, _) = tiny_model();
        let eng = Engine::rust();
        let want = model.predict(&eng, &x.slice_rows(0, 1)).unwrap()[0];
        let server = Server::start(
            model,
            ServeConfig {
                engine: "rust".into(),
                ..Default::default()
            },
        )
        .unwrap();
        let h = server.handle();
        let (reply_tx, reply_rx) = channel();
        h.tx.send(RowsRequest {
            x: vec![1.0],
            rows: 1,
            reply: reply_tx,
        })
        .unwrap();
        let err = reply_rx.recv().unwrap().unwrap_err();
        assert!(format!("{err:#}").contains("model dim"), "{err:#}");
        let got = h.predict(x.row(0).to_vec()).unwrap();
        assert!((got - want).abs() < 1e-12, "server must still serve");
        let stats = server.stop();
        // the rejected request is counted, not silently dropped, and it
        // never skews the executed-batch row mean
        assert_eq!(stats.requests, 2);
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.rows, 1);
    }

    #[test]
    fn multiclass_serve_loop_survives_malformed_queue_request_and_counts_it() {
        let (model, x, _) = tiny_multiclass();
        let server = MulticlassServer::start(
            model,
            ServeConfig {
                engine: "rust".into(),
                ..Default::default()
            },
        )
        .unwrap();
        let h = server.handle();
        let (reply_tx, reply_rx) = channel();
        h.tx.send(RowsRequest {
            x: vec![0.5, 0.5],
            rows: 1,
            reply: reply_tx,
        })
        .unwrap();
        let err = reply_rx.recv().unwrap().unwrap_err();
        assert!(format!("{err:#}").contains("model dim"), "{err:#}");
        let got = h.predict(x.row(0).to_vec()).unwrap();
        assert!(got.class < 3, "server must still serve");
        let stats = server.stop();
        assert_eq!(stats.requests, 2);
        assert_eq!(stats.rejected, 1);
    }

    #[test]
    fn bulk_predict_source_matches_in_memory_predict() {
        let (model, x, y) = tiny_model();
        let eng = Engine::rust();
        let want = model.predict(&eng, &x).unwrap();
        let data = crate::data::Dataset::new_regression("bulk", x, y.clone());
        let mut src = crate::data::MemSource::new(data, 77);
        let score = predict_source(&model, &eng, &mut src).unwrap();
        assert_eq!(score.preds, want);
        assert_eq!(score.targets, y);
        assert_eq!(score.rows, want.len());
        assert_eq!(score.skipped_rows, 0);
        // only one 77-row chunk of features was ever resident
        assert_eq!(score.max_chunk_bytes, 77 * model.centers.cols * 8);
        // dimension mismatch is rejected up front
        let bad = crate::data::Dataset::new_regression(
            "bad",
            Mat::zeros(4, model.centers.cols + 1),
            vec![0.0; 4],
        );
        let mut bad_src = crate::data::MemSource::new(bad, 4);
        assert!(predict_source(&model, &eng, &mut bad_src).is_err());
    }

    #[test]
    fn bulk_predict_f32_source_halves_resident_bytes_within_model() {
        use crate::kernels::tol;
        use crate::linalg::mat32::{Dtype, MatF32};
        let (model, x, y) = tiny_model();
        let eng = Engine::rust();
        // oracle: f64 predict on the rounded-and-widened features, so the
        // comparison isolates the compute tier from storage rounding
        let xr = MatF32::from_mat(&x);
        let want = model.predict(&eng, &xr.to_mat()).unwrap();
        let bound = tol::predict_bound(
            model.config.kernel,
            &xr,
            &MatF32::from_mat(&model.centers),
            &model.alpha,
        );
        let data = crate::data::Dataset::new_regression("bulk32", x, y.clone());
        let mut src = crate::data::MemSource::with_dtype(data, 77, Dtype::F32);
        let score = predict_source(&model, &eng, &mut src).unwrap();
        assert_eq!(score.targets, y);
        assert_eq!(score.rows, want.len());
        for (i, (&got, &w)) in score.preds.iter().zip(&want).enumerate() {
            assert!((got - w).abs() <= bound, "row {i}: {got} vs {w} (bound {bound:.3e})");
        }
        // the peak-chunk proxy must report 4 bytes/element, not 8
        assert_eq!(score.max_chunk_bytes, 77 * model.centers.cols * 4);
    }
}

//! Network front door: a std-only TCP server for the serving subsystem.
//!
//! Thread-per-connection over [`TcpListener`], speaking a small
//! length-prefixed binary protocol (see the frame layout below and
//! DESIGN.md §Serving). Connections do **no** model work themselves:
//! every predict request is forwarded onto the per-model queue drained
//! by the shared admission batcher (`serve`'s private `batch` module),
//! so concurrent
//! requests from *different sockets* coalesce into the same panel-sized
//! predict sweeps as in-process callers — the MulticlassServer
//! amortization trick applied across connections.
//!
//! Models are served by name from a [`ModelRegistry`]; the swap op
//! hot-swaps a name atomically ([`ModelSlot`] RCU) without dropping
//! in-flight requests. One model-worker thread is spawned per
//! registered name (engines are thread-local), so differently-named
//! models batch independently.
//!
//! ## Frame layout
//!
//! Every message (both directions) is `u32 LE body length` + body,
//! capped at [`MAX_FRAME`]. Integers are little-endian; f64s travel as
//! raw IEEE-754 bits ([`crate::util::wire`]), which is what makes
//! network predictions bitwise-equal to direct `model.predict`.
//!
//! Request body: `u8 op` + op-specific fields. Strings are u32
//! length-prefixed UTF-8.
//!
//! | op | fields | ok payload |
//! |----|--------|------------|
//! | 1 `predict_one` | name, u32 d, d×f64 | f64 |
//! | 2 `predict_batch` | name, u32 rows, u32 d, rows·d×f64 | u32 rows, rows×f64 |
//! | 3 `predict_class` | name, u32 rows, u32 d, rows·d×f64 | u32 rows, u32 k, rows×(u32 class, k×f64) |
//! | 4 `score_shard` | name, path, u32 chunk_rows | u64 rows, u64 skipped, u64 max_chunk_bytes, f64 mse, f64 rmse |
//! | 5 `stats` | name | u64 requests, u64 rejected, u64 batches, u64 rows, f64 mean_batch, u64 engine_fallbacks, u64 swaps |
//! | 6 `swap` | name, path | u64 generation |
//!
//! Response body: `u8 status` (0 = ok, 1 = error) + ok payload or a
//! string error message. A malformed or unserviceable request gets a
//! typed error frame and fails alone — the connection and the server
//! keep going.

use super::batch::{engine_or_fallback, RowsReply, RowsRequest, StatsCell, IDLE_POLL};
use super::registry::{ModelRegistry, ModelSlot, ServedModel};
use super::{predict_source, ServeConfig, ServeEvent, ServeStats};
use crate::util::wire::{Reader, Writer};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Hard cap on one frame body — a hostile or corrupt length prefix must
/// not allocate unbounded memory (64 MiB ≈ an 8M-float batch request).
pub const MAX_FRAME: usize = 64 << 20;

pub const OP_PREDICT_ONE: u8 = 1;
pub const OP_PREDICT_BATCH: u8 = 2;
pub const OP_PREDICT_CLASS: u8 = 3;
pub const OP_SCORE_SHARD: u8 = 4;
pub const OP_STATS: u8 = 5;
pub const OP_SWAP: u8 = 6;

const STATUS_OK: u8 = 0;
const STATUS_ERR: u8 = 1;

/// How long a connection write may stall before the connection is
/// dropped (a dead client must not wedge its handler thread forever).
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// One running model worker behind the network server (owned by
/// [`NetServer`]; connection threads get per-connection `Sender` clones
/// plus the `Sync` stats/slot handles).
struct Worker {
    tx: Sender<RowsRequest>,
    stop: Sender<()>,
    stats: Arc<StatsCell>,
    join: std::thread::JoinHandle<ServeStats>,
}

/// The per-model handles a connection needs to route a request.
struct Route {
    tx: Sender<RowsRequest>,
    stats: Arc<StatsCell>,
    slot: Arc<ModelSlot>,
}

/// State shared between the accept loop, connection threads and
/// [`NetServer::stop`]. Senders are deliberately *not* in here (mpsc
/// senders are cloned per connection at accept time).
struct Shared {
    registry: Arc<ModelRegistry>,
    cfg: ServeConfig,
    stop: Arc<AtomicBool>,
}

/// The TCP serving front door. `start` binds, spawns one model worker
/// per registered name plus the accept thread, and returns immediately;
/// `stop` shuts everything down in dependency order and returns the
/// per-model stats.
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_join: std::thread::JoinHandle<()>,
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    workers: BTreeMap<String, Worker>,
    registry: Arc<ModelRegistry>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// serve every model currently registered. Models registered after
    /// `start` are not served (workers are spawned here, once).
    pub fn start(registry: Arc<ModelRegistry>, cfg: ServeConfig, addr: &str) -> Result<NetServer> {
        anyhow::ensure!(!registry.is_empty(), "no models registered to serve");
        let listener = TcpListener::bind(addr).map_err(|e| anyhow!("binding {addr}: {e}"))?;
        let local = listener
            .local_addr()
            .map_err(|e| anyhow!("resolving bound address: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| anyhow!("nonblocking listener: {e}"))?;
        let stop = Arc::new(AtomicBool::new(false));

        // one worker thread per registered name: engines are per-thread,
        // and per-name queues keep differently-named models batching
        // independently
        let mut workers = BTreeMap::new();
        let mut routes = BTreeMap::new();
        for name in registry.names() {
            let slot = match registry.get(&name) {
                Some(s) => s,
                None => continue,
            };
            let (tx, rx) = channel::<RowsRequest>();
            let (stop_tx, stop_rx) = channel::<()>();
            let stats = Arc::new(StatsCell::default());
            let wcfg = cfg.clone();
            let wslot = slot.clone();
            let wstats = stats.clone();
            let join = std::thread::Builder::new()
                .name(format!("falkon-net-{name}"))
                .spawn(move || super::batch::run_model_worker(wslot, wcfg, rx, stop_rx, wstats))
                .map_err(|e| anyhow!("spawning worker for {name:?}: {e}"))?;
            routes.insert(
                name.clone(),
                Route {
                    tx: tx.clone(),
                    stats: stats.clone(),
                    slot,
                },
            );
            workers.insert(
                name,
                Worker {
                    tx,
                    stop: stop_tx,
                    stats,
                    join,
                },
            );
        }

        let shared = Arc::new(Shared {
            registry: registry.clone(),
            cfg: cfg.clone(),
            stop: stop.clone(),
        });
        let conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept_conns = conns.clone();
        let accept_stop = stop.clone();
        let accept_join = std::thread::Builder::new()
            .name("falkon-net-accept".into())
            .spawn(move || {
                accept_loop(listener, shared, routes, accept_conns, accept_stop);
            })
            .map_err(|e| anyhow!("spawning accept thread: {e}"))?;

        Ok(NetServer {
            addr: local,
            stop,
            accept_join,
            conns,
            workers,
            registry,
        })
    }

    /// The bound address (useful with `"127.0.0.1:0"`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The registry this server routes from (swaps through it are live).
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Live stats snapshot for one served model.
    pub fn stats(&self, name: &str) -> Option<ServeStats> {
        self.workers.get(name).map(|w| w.stats.snapshot())
    }

    /// Shut down in dependency order: stop accepting, join connection
    /// handlers (workers stay alive so in-flight replies drain — no
    /// request is dropped), then disconnect + stop the model workers and
    /// collect their final stats.
    pub fn stop(self) -> BTreeMap<String, ServeStats> {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.accept_join.join();
        let handles = {
            let mut conns = self.conns.lock().unwrap_or_else(|p| p.into_inner());
            std::mem::take(&mut *conns)
        };
        for h in handles {
            let _ = h.join();
        }
        let mut out = BTreeMap::new();
        for (name, w) in self.workers {
            let Worker { tx, stop, join, stats } = w;
            // every connection-held clone is gone (handlers joined), so
            // dropping the master sender disconnects the queue; the stop
            // signal covers the idle-poll window
            drop(tx);
            let _ = stop.send(());
            let final_stats = join.join().unwrap_or_else(|_| stats.snapshot());
            out.insert(name, final_stats);
        }
        out
    }
}

fn accept_loop(
    listener: TcpListener,
    shared: Arc<Shared>,
    routes: BTreeMap<String, Route>,
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    stop: Arc<AtomicBool>,
) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // per-connection route table: cloned senders (mpsc
                // senders are Send, so each handler owns its own) plus
                // shared atomics/slots
                let conn_routes: BTreeMap<String, Route> = routes
                    .iter()
                    .map(|(k, r)| {
                        (
                            k.clone(),
                            Route {
                                tx: r.tx.clone(),
                                stats: r.stats.clone(),
                                slot: r.slot.clone(),
                            },
                        )
                    })
                    .collect();
                let conn_shared = shared.clone();
                let spawned = std::thread::Builder::new()
                    .name("falkon-net-conn".into())
                    .spawn(move || serve_connection(stream, conn_shared, conn_routes));
                match spawned {
                    Ok(h) => {
                        let mut guard = conns.lock().unwrap_or_else(|p| p.into_inner());
                        guard.push(h);
                    }
                    Err(e) => eprintln!("[serve] connection thread spawn failed: {e}"),
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                eprintln!("[serve] accept error: {e}");
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

enum FrameRead {
    Frame(Vec<u8>),
    /// clean EOF at a frame boundary, or server shutdown
    Closed,
}

/// Read exactly `buf.len()` bytes, re-checking the stop flag on every
/// read timeout. A manual loop rather than `read_exact`: `read_exact`
/// discards already-read bytes on timeout, which would corrupt framing
/// for a client that writes a frame slowly.
fn read_full(stream: &mut TcpStream, buf: &mut [u8], stop: &AtomicBool) -> Result<FrameRead> {
    let mut off = 0usize;
    while off < buf.len() {
        match stream.read(&mut buf[off..]) {
            Ok(0) => {
                if off == 0 {
                    return Ok(FrameRead::Closed);
                }
                return Err(anyhow!("connection closed mid-frame ({off} bytes read)"));
            }
            Ok(n) => off += n,
            Err(e) => {
                let retriable = matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                );
                if !retriable {
                    return Err(anyhow!("socket read: {e}"));
                }
                if stop.load(Ordering::SeqCst) {
                    return Ok(FrameRead::Closed);
                }
            }
        }
    }
    Ok(FrameRead::Frame(Vec::new()))
}

/// Read one length-prefixed frame body.
fn read_frame(stream: &mut TcpStream, stop: &AtomicBool) -> Result<FrameRead> {
    let mut len_buf = [0u8; 4];
    match read_full(stream, &mut len_buf, stop)? {
        FrameRead::Closed => return Ok(FrameRead::Closed),
        FrameRead::Frame(_) => {}
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    anyhow::ensure!(len <= MAX_FRAME, "frame of {len} bytes exceeds cap {MAX_FRAME}");
    let mut body = vec![0u8; len];
    match read_full(stream, &mut body, stop)? {
        FrameRead::Closed => Ok(FrameRead::Closed),
        FrameRead::Frame(_) => Ok(FrameRead::Frame(body)),
    }
}

fn write_frame(stream: &mut TcpStream, body: &[u8]) -> Result<()> {
    let len = body.len() as u32;
    stream
        .write_all(&len.to_le_bytes())
        .and_then(|_| stream.write_all(body))
        .map_err(|e| anyhow!("socket write: {e}"))
}

fn ok_frame(payload: Writer) -> Vec<u8> {
    let mut w = Writer::new();
    w.u8(STATUS_OK);
    let mut body = w.into_bytes();
    body.extend_from_slice(&payload.into_bytes());
    body
}

fn err_frame(msg: &str) -> Vec<u8> {
    let mut w = Writer::new();
    w.u8(STATUS_ERR).str_u32(msg);
    w.into_bytes()
}

fn serve_connection(mut stream: TcpStream, shared: Arc<Shared>, routes: BTreeMap<String, Route>) {
    if stream.set_read_timeout(Some(IDLE_POLL)).is_err()
        || stream.set_write_timeout(Some(WRITE_TIMEOUT)).is_err()
    {
        return;
    }
    // small frames both ways: Nagle + delayed ACK would add ~40ms per
    // round trip, swamping the admission batcher's max_wait
    let _ = stream.set_nodelay(true);
    loop {
        let body = match read_frame(&mut stream, &shared.stop) {
            Ok(FrameRead::Frame(b)) => b,
            Ok(FrameRead::Closed) => return,
            Err(e) => {
                // framing is unrecoverable after a bad length/short read:
                // best-effort error frame, then close
                let _ = write_frame(&mut stream, &err_frame(&format!("{e:#}")));
                return;
            }
        };
        let reply = match handle_request(&body, &shared, &routes) {
            Ok(frame) => frame,
            Err(e) => err_frame(&format!("{e:#}")),
        };
        if write_frame(&mut stream, &reply).is_err() {
            return;
        }
    }
}

/// Dispatch one request frame; any `Err` becomes an error frame for
/// this request only.
fn handle_request(
    body: &[u8],
    shared: &Shared,
    routes: &BTreeMap<String, Route>,
) -> Result<Vec<u8>> {
    let mut r = Reader::new(body);
    let op = r.u8()?;
    let name = r.str_u32()?.to_string();
    let Some(route) = routes.get(&name) else {
        return Err(anyhow!(
            "unknown model {name:?} (serving: {:?})",
            shared.registry.names()
        ));
    };
    match op {
        OP_PREDICT_ONE => {
            let d = r.u32()? as usize;
            let x = r.f64s(d)?;
            r.done()?;
            match forward(route, x, 1)? {
                RowsReply::Scalars(p) => {
                    let v = p
                        .first()
                        .copied()
                        .ok_or_else(|| anyhow!("empty prediction batch"))?;
                    let mut w = Writer::new();
                    w.f64(v);
                    Ok(ok_frame(w))
                }
                RowsReply::Classes(_) => Err(anyhow!(
                    "model {name:?} is multiclass; use the predict_class op"
                )),
            }
        }
        OP_PREDICT_BATCH => {
            let rows = r.u32()? as usize;
            let d = r.u32()? as usize;
            let count = rows
                .checked_mul(d)
                .ok_or_else(|| anyhow!("rows*d overflow"))?;
            let x = r.f64s(count)?;
            r.done()?;
            match forward(route, x, rows)? {
                RowsReply::Scalars(p) => {
                    let mut w = Writer::new();
                    w.u32(p.len() as u32).f64s(&p);
                    Ok(ok_frame(w))
                }
                RowsReply::Classes(_) => Err(anyhow!(
                    "model {name:?} is multiclass; use the predict_class op"
                )),
            }
        }
        OP_PREDICT_CLASS => {
            let rows = r.u32()? as usize;
            let d = r.u32()? as usize;
            let count = rows
                .checked_mul(d)
                .ok_or_else(|| anyhow!("rows*d overflow"))?;
            let x = r.f64s(count)?;
            r.done()?;
            match forward(route, x, rows)? {
                RowsReply::Classes(p) => {
                    let k = p.first().map(|c| c.scores.len()).unwrap_or(0);
                    let mut w = Writer::new();
                    w.u32(p.len() as u32).u32(k as u32);
                    for c in &p {
                        w.u32(c.class as u32).f64s(&c.scores);
                    }
                    Ok(ok_frame(w))
                }
                RowsReply::Scalars(_) => Err(anyhow!(
                    "model {name:?} is a regression model; use the predict ops"
                )),
            }
        }
        OP_SCORE_SHARD => {
            let path = r.str_u32()?.to_string();
            let chunk_rows = r.u32()? as usize;
            r.done()?;
            score_shard(route, shared, &path, chunk_rows)
        }
        OP_STATS => {
            r.done()?;
            let s = route.stats.snapshot();
            let mut w = Writer::new();
            w.u64(s.requests)
                .u64(s.rejected)
                .u64(s.batches)
                .u64(s.rows)
                .f64(s.mean_batch)
                .u64(s.engine_fallbacks)
                .u64(route.slot.swaps());
            Ok(ok_frame(w))
        }
        OP_SWAP => {
            let path = r.str_u32()?.to_string();
            r.done()?;
            let generation = shared.registry.swap_file(&name, &path)?;
            let event = ServeEvent::ModelSwapped {
                model: name,
                generation,
            };
            eprintln!("[serve] {event}");
            let mut w = Writer::new();
            w.u64(generation);
            Ok(ok_frame(w))
        }
        other => Err(anyhow!("unknown op {other}")),
    }
}

/// Enqueue one request onto the model's batching queue and wait for the
/// fan-out reply. Shape validation happens in the worker, against the
/// model generation that actually serves the batch.
fn forward(route: &Route, x: Vec<f64>, rows: usize) -> Result<RowsReply> {
    let (reply_tx, reply_rx) = channel();
    route
        .tx
        .send(RowsRequest {
            x,
            rows,
            reply: reply_tx,
        })
        .map_err(|_| anyhow!("model worker stopped"))?;
    reply_rx
        .recv()
        .map_err(|_| anyhow!("model worker dropped the request"))?
}

/// Bulk-score a shard file through [`predict_source`] on the connection
/// thread (its own engine — the batching queue is for latency-sensitive
/// row requests, not multi-minute scans).
fn score_shard(route: &Route, shared: &Shared, path: &str, chunk_rows: usize) -> Result<Vec<u8>> {
    let (model, _gen) = route.slot.current();
    let m = match &*model {
        ServedModel::Regression(m) => m,
        ServedModel::Multiclass(_) => {
            return Err(anyhow!("score_shard serves regression models only"))
        }
    };
    let engine = engine_or_fallback(&shared.cfg.engine, shared.cfg.workers, &route.stats);
    let mut src = crate::data::shard::ShardSource::open(path, chunk_rows.max(1))?;
    let score = predict_source(m, &engine, &mut src)?;
    let (mse, rmse) = if score.rows > 0 {
        (
            crate::metrics::mse(&score.preds, &score.targets),
            crate::metrics::rmse(&score.preds, &score.targets),
        )
    } else {
        (f64::NAN, f64::NAN)
    };
    let mut w = Writer::new();
    w.u64(score.rows as u64)
        .u64(score.skipped_rows as u64)
        .u64(score.max_chunk_bytes as u64)
        .f64(mse)
        .f64(rmse);
    Ok(ok_frame(w))
}

// ---------------------------------------------------------------------
// client
// ---------------------------------------------------------------------

/// Stats reply of the stats op.
#[derive(Debug, Clone, Default)]
pub struct NetStats {
    pub serve: ServeStats,
    /// completed hot swaps on this model's slot
    pub swaps: u64,
}

/// Shard-scoring reply of the score_shard op.
#[derive(Debug, Clone)]
pub struct ShardScore {
    pub rows: u64,
    pub skipped_rows: u64,
    pub max_chunk_bytes: u64,
    pub mse: f64,
    pub rmse: f64,
}

/// Blocking client for the network protocol — one request in flight per
/// client; open several clients for concurrency (the server batches
/// across connections).
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).map_err(|e| anyhow!("connecting {addr}: {e}"))?;
        stream
            .set_nodelay(true)
            .map_err(|e| anyhow!("setting nodelay: {e}"))?;
        Ok(Client { stream })
    }

    /// One round trip: send a request body, return the ok payload or the
    /// server's error message as a typed error.
    fn call(&mut self, body: &[u8]) -> Result<Vec<u8>> {
        write_frame(&mut self.stream, body)?;
        let mut len_buf = [0u8; 4];
        self.stream
            .read_exact(&mut len_buf)
            .map_err(|e| anyhow!("reading reply length: {e}"))?;
        let len = u32::from_le_bytes(len_buf) as usize;
        anyhow::ensure!(len <= MAX_FRAME, "reply of {len} bytes exceeds cap");
        let mut reply = vec![0u8; len];
        self.stream
            .read_exact(&mut reply)
            .map_err(|e| anyhow!("reading reply body: {e}"))?;
        let mut r = Reader::new(&reply);
        match r.u8()? {
            STATUS_OK => Ok(reply[1..].to_vec()),
            STATUS_ERR => Err(anyhow!("server: {}", r.str_u32()?)),
            other => Err(anyhow!("bad status byte {other}")),
        }
    }

    /// Predict one feature row.
    pub fn predict_one(&mut self, model: &str, x: &[f64]) -> Result<f64> {
        let mut w = Writer::new();
        w.u8(OP_PREDICT_ONE)
            .str_u32(model)
            .u32(x.len() as u32)
            .f64s(x);
        let reply = self.call(&w.into_bytes())?;
        let mut r = Reader::new(&reply);
        let v = r.f64()?;
        r.done()?;
        Ok(v)
    }

    /// Predict `rows` feature rows (row-major, `x.len() == rows * d`) in
    /// one request — served as one admission unit of `rows` rows.
    pub fn predict_batch(&mut self, model: &str, rows: usize, x: &[f64]) -> Result<Vec<f64>> {
        anyhow::ensure!(rows > 0 && x.len() % rows == 0, "x.len() must be rows * d");
        let d = x.len() / rows;
        let mut w = Writer::new();
        w.u8(OP_PREDICT_BATCH)
            .str_u32(model)
            .u32(rows as u32)
            .u32(d as u32)
            .f64s(x);
        let reply = self.call(&w.into_bytes())?;
        let mut r = Reader::new(&reply);
        let n = r.u32()? as usize;
        let p = r.f64s(n)?;
        r.done()?;
        Ok(p)
    }

    /// Multiclass: argmax class + per-class scores for each row.
    pub fn predict_class(
        &mut self,
        model: &str,
        rows: usize,
        x: &[f64],
    ) -> Result<Vec<super::ClassPrediction>> {
        anyhow::ensure!(rows > 0 && x.len() % rows == 0, "x.len() must be rows * d");
        let d = x.len() / rows;
        let mut w = Writer::new();
        w.u8(OP_PREDICT_CLASS)
            .str_u32(model)
            .u32(rows as u32)
            .u32(d as u32)
            .f64s(x);
        let reply = self.call(&w.into_bytes())?;
        let mut r = Reader::new(&reply);
        let n = r.u32()? as usize;
        let k = r.u32()? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let class = r.u32()? as usize;
            let scores = r.f64s(k)?;
            out.push(super::ClassPrediction { class, scores });
        }
        r.done()?;
        Ok(out)
    }

    /// Bulk-score a shard file that lives on the *server's* filesystem.
    pub fn score_shard(
        &mut self,
        model: &str,
        path: &str,
        chunk_rows: usize,
    ) -> Result<ShardScore> {
        let mut w = Writer::new();
        w.u8(OP_SCORE_SHARD)
            .str_u32(model)
            .str_u32(path)
            .u32(chunk_rows as u32);
        let reply = self.call(&w.into_bytes())?;
        let mut r = Reader::new(&reply);
        let score = ShardScore {
            rows: r.u64()?,
            skipped_rows: r.u64()?,
            max_chunk_bytes: r.u64()?,
            mse: r.f64()?,
            rmse: r.f64()?,
        };
        r.done()?;
        Ok(score)
    }

    /// Live serving stats for one model.
    pub fn stats(&mut self, model: &str) -> Result<NetStats> {
        let mut w = Writer::new();
        w.u8(OP_STATS).str_u32(model);
        let reply = self.call(&w.into_bytes())?;
        let mut r = Reader::new(&reply);
        let serve = ServeStats {
            requests: r.u64()?,
            rejected: r.u64()?,
            batches: r.u64()?,
            rows: r.u64()?,
            mean_batch: r.f64()?,
            engine_fallbacks: r.u64()?,
        };
        let swaps = r.u64()?;
        r.done()?;
        Ok(NetStats { serve, swaps })
    }

    /// Hot-swap a served model from a file on the *server's* filesystem;
    /// returns the new generation. In-flight requests finish on the old
    /// model; every later batch sees the new one.
    pub fn swap(&mut self, model: &str, path: &str) -> Result<u64> {
        let mut w = Writer::new();
        w.u8(OP_SWAP).str_u32(model).str_u32(path);
        let reply = self.call(&w.into_bytes())?;
        let mut r = Reader::new(&reply);
        let generation = r.u64()?;
        r.done()?;
        Ok(generation)
    }
}

//! Named-model registry with atomic hot swap — the model-management half
//! of the network front door (`serve/net.rs`).
//!
//! Each served name owns a [`ModelSlot`]: an RCU-style
//! `Mutex<Arc<ServedModel>>`. The serve loop snapshots the `Arc` **once
//! per executed batch**, so a [`ModelSlot::swap`] never tears a request:
//! in-flight batches finish on the model they started with (the old
//! `Arc` stays alive until the last batch drops it), and the very next
//! batch sees the new model — zero requests dropped, zero mixed answers.
//! The lock is held only for the pointer clone, never across a predict.
//!
//! Models load through [`crate::falkon::model_io`]; [`load_served`]
//! sniffs the `format` field so one registry serves regression and
//! multiclass models side by side.

use crate::falkon::{model_io, FalkonModel, FalkonMulticlass};
use crate::util::json;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// A model the serving layer can answer requests with.
pub enum ServedModel {
    Regression(FalkonModel),
    Multiclass(FalkonMulticlass),
}

impl ServedModel {
    /// Feature dimension requests must match.
    pub fn d(&self) -> usize {
        match self {
            ServedModel::Regression(m) => m.centers.cols,
            ServedModel::Multiclass(m) => m.centers.cols,
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            ServedModel::Regression(_) => "regression",
            ServedModel::Multiclass(_) => "multiclass",
        }
    }
}

/// One named serving slot: the current model plus a generation counter
/// bumped on every swap (used to invalidate per-model worker caches and
/// reported through the stats op).
pub struct ModelSlot {
    current: Mutex<Arc<ServedModel>>,
    generation: AtomicU64,
}

impl ModelSlot {
    pub fn new(model: ServedModel) -> ModelSlot {
        ModelSlot {
            current: Mutex::new(Arc::new(model)),
            generation: AtomicU64::new(0),
        }
    }

    /// Snapshot the served model. Callers hold the returned `Arc` for
    /// the duration of one batch; a concurrent swap does not affect it.
    pub fn current(&self) -> (Arc<ServedModel>, u64) {
        // a poisoned lock only means a panicking thread held it during
        // the pointer clone; the Arc inside is still valid — recover
        // rather than take the serving path down
        let guard = self.current.lock().unwrap_or_else(|p| p.into_inner());
        (guard.clone(), self.generation.load(Ordering::Acquire))
    }

    /// Atomically replace the served model (RCU: readers keep the old
    /// `Arc` until their batch completes). Returns the new generation.
    pub fn swap(&self, model: ServedModel) -> u64 {
        let mut guard = self.current.lock().unwrap_or_else(|p| p.into_inner());
        *guard = Arc::new(model);
        // fetch_add while still holding the lock so generation and model
        // move together (stats may observe them slightly apart, but a
        // worker snapshotting via `current` sees a consistent pair)
        self.generation.fetch_add(1, Ordering::AcqRel) + 1
    }

    /// Number of completed swaps.
    pub fn swaps(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }
}

/// Registry of named [`ModelSlot`]s behind the network server. Names
/// are registered before the server starts (one model worker is spawned
/// per name); [`ModelRegistry::swap`] hot-swaps an existing name.
#[derive(Default)]
pub struct ModelRegistry {
    slots: RwLock<BTreeMap<String, Arc<ModelSlot>>>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Register (or replace the slot of) a named model.
    pub fn insert(&self, name: &str, model: ServedModel) {
        let slot = Arc::new(ModelSlot::new(model));
        let mut slots = self.slots.write().unwrap_or_else(|p| p.into_inner());
        slots.insert(name.to_string(), slot);
    }

    pub fn get(&self, name: &str) -> Option<Arc<ModelSlot>> {
        let slots = self.slots.read().unwrap_or_else(|p| p.into_inner());
        slots.get(name).cloned()
    }

    pub fn names(&self) -> Vec<String> {
        let slots = self.slots.read().unwrap_or_else(|p| p.into_inner());
        slots.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        let slots = self.slots.read().unwrap_or_else(|p| p.into_inner());
        slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hot-swap an existing named model; returns the new generation.
    /// Unknown names are a typed error — new names need a model worker,
    /// which only [`super::net::NetServer::start`] spawns.
    pub fn swap(&self, name: &str, model: ServedModel) -> Result<u64> {
        let slot = self
            .get(name)
            .ok_or_else(|| anyhow!("unknown model {name:?} (registered: {:?})", self.names()))?;
        Ok(slot.swap(model))
    }

    /// Load a model file into a named slot (registration-time helper).
    pub fn load_file(&self, name: &str, path: &str) -> Result<()> {
        self.insert(name, load_served(path)?);
        Ok(())
    }

    /// Hot-swap an existing name from a model file.
    pub fn swap_file(&self, name: &str, path: &str) -> Result<u64> {
        self.swap(name, load_served(path)?)
    }
}

/// Load either model kind from a JSON file written by
/// [`model_io::save`] / [`model_io::save_multiclass`], dispatching on
/// the embedded `format` tag.
pub fn load_served(path: &str) -> Result<ServedModel> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading model file {path}: {e}"))?;
    let v = json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))?;
    match v.get("format").as_str() {
        Some(model_io::FORMAT_REGRESSION) => {
            Ok(ServedModel::Regression(model_io::model_from_json(&v)?))
        }
        Some(model_io::FORMAT_MULTICLASS) => {
            Ok(ServedModel::Multiclass(model_io::multiclass_from_json(&v)?))
        }
        other => Err(anyhow!("{path}: not a falkon model file (format {other:?})")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::falkon::FalkonConfig;
    use crate::runtime::Engine;
    use crate::util::rng::Rng;

    fn tiny(seed: u64) -> FalkonModel {
        let mut rng = Rng::new(seed);
        let data = synth::smooth_regression(&mut rng, 200, 3, 0.05);
        let eng = Engine::rust();
        let cfg = FalkonConfig {
            sigma: 1.5,
            lam: 1e-4,
            m: 16,
            t: 8,
            ..Default::default()
        };
        crate::falkon::fit(&eng, &data.x, &data.y, &cfg).unwrap()
    }

    #[test]
    fn swap_bumps_generation_and_keeps_old_arc_alive() {
        let slot = ModelSlot::new(ServedModel::Regression(tiny(1)));
        let (before, g0) = slot.current();
        assert_eq!(g0, 0);
        let g1 = slot.swap(ServedModel::Regression(tiny(2)));
        assert_eq!(g1, 1);
        assert_eq!(slot.swaps(), 1);
        let (after, g) = slot.current();
        assert_eq!(g, 1);
        // RCU: the pre-swap snapshot still serves (in-flight batches)
        assert!(!Arc::ptr_eq(&before, &after));
        assert_eq!(before.d(), 3);
    }

    #[test]
    fn registry_swap_requires_known_name() {
        let reg = ModelRegistry::new();
        reg.insert("a", ServedModel::Regression(tiny(1)));
        assert!(reg.swap("a", ServedModel::Regression(tiny(2))).is_ok());
        let err = reg
            .swap("missing", ServedModel::Regression(tiny(3)))
            .unwrap_err();
        assert!(err.to_string().contains("unknown model"), "{err}");
        assert_eq!(reg.names(), vec!["a".to_string()]);
    }

    #[test]
    fn load_served_dispatches_on_format() {
        let model = tiny(5);
        let dir = std::env::temp_dir();
        let path = dir.join("falkon_registry_reg.json");
        let path = path.to_str().unwrap();
        model_io::save(&model, path).unwrap();
        match load_served(path).unwrap() {
            ServedModel::Regression(m) => assert_eq!(m.centers.rows, model.centers.rows),
            ServedModel::Multiclass(_) => panic!("wrong kind"),
        }
        let bad = dir.join("falkon_registry_bad.json");
        std::fs::write(&bad, "{\"format\": \"other\"}").unwrap();
        assert!(load_served(bad.to_str().unwrap()).is_err());
        let _ = std::fs::remove_file(path);
        let _ = std::fs::remove_file(bad);
    }
}

//! Fault-tolerance substrate (DESIGN.md §Fault tolerance): a typed
//! transient/fatal error taxonomy carried through `anyhow` chains, a
//! bounded-retry policy with exponential backoff, a deterministic
//! seeded fault-injection [`DataSource`] wrapper so every robustness
//! claim is exercised by tests and the `--inject-faults` bench mode,
//! and FNV-1a fingerprints that bind checkpoint sidecars to the run
//! that wrote them.
//!
//! The injector fails **before** touching the inner source, so a
//! retried read re-delivers exactly the chunk the fault suppressed and
//! the recovered stream is bit-identical to the fault-free one — which
//! is what lets the streamed-fit determinism contract survive injected
//! I/O faults.

use crate::data::source::{Chunk, DataSource};
use anyhow::Result;
use std::collections::BTreeMap;
use std::fmt;

/// Whether an error is worth retrying.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorClass {
    /// momentary I/O hiccup — a bounded retry may succeed
    Transient,
    /// corrupt data, logic error, exhausted budget — fail fast
    Fatal,
}

/// A typed fault that travels inside an [`anyhow::Error`] chain so call
/// sites can classify without string matching.
#[derive(Debug, Clone)]
pub struct FaultError {
    pub class: ErrorClass,
    pub what: String,
}

impl fmt::Display for FaultError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let tag = match self.class {
            ErrorClass::Transient => "transient",
            ErrorClass::Fatal => "fatal",
        };
        write!(f, "{tag} fault: {}", self.what)
    }
}

impl std::error::Error for FaultError {}

impl FaultError {
    pub fn transient(what: impl Into<String>) -> anyhow::Error {
        anyhow::Error::new(FaultError {
            class: ErrorClass::Transient,
            what: what.into(),
        })
    }

    pub fn fatal(what: impl Into<String>) -> anyhow::Error {
        anyhow::Error::new(FaultError {
            class: ErrorClass::Fatal,
            what: what.into(),
        })
    }
}

/// Classify an error chain: an embedded [`FaultError`] decides directly;
/// interrupted/timed-out I/O is transient; everything else is fatal
/// (parse errors, contiguity violations, dimension mismatches must not
/// be retried — re-reading corrupt data cannot fix it).
pub fn classify(err: &anyhow::Error) -> ErrorClass {
    for cause in err.chain() {
        if let Some(f) = cause.downcast_ref::<FaultError>() {
            return f.class;
        }
        if let Some(io) = cause.downcast_ref::<std::io::Error>() {
            use std::io::ErrorKind::*;
            if matches!(io.kind(), Interrupted | WouldBlock | TimedOut) {
                return ErrorClass::Transient;
            }
        }
    }
    ErrorClass::Fatal
}

/// Bounded retry with exponential backoff. `max_retries` is the number
/// of **re**-attempts after the first failure; backoff doubles from
/// `base_backoff_ms` and is capped at 1 s per wait.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    pub max_retries: u32,
    pub base_backoff_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 4,
            base_backoff_ms: 5,
        }
    }
}

impl RetryPolicy {
    /// Never retry (every error is terminal).
    pub fn none() -> RetryPolicy {
        RetryPolicy {
            max_retries: 0,
            base_backoff_ms: 0,
        }
    }

    /// Backoff before re-attempt `attempt` (0-based), in milliseconds.
    pub fn backoff_ms(&self, attempt: u32) -> u64 {
        let shifted = self
            .base_backoff_ms
            .saturating_mul(1u64 << attempt.min(20));
        shifted.min(1000)
    }

    /// Run `f`, retrying transient failures up to `max_retries` times.
    /// Fatal errors and retry exhaustion return immediately with
    /// `what` attached for context.
    pub fn run<T>(&self, what: &str, mut f: impl FnMut() -> Result<T>) -> Result<T> {
        let mut attempt: u32 = 0;
        loop {
            match f() {
                Ok(v) => return Ok(v),
                Err(e) => {
                    if classify(&e) == ErrorClass::Fatal {
                        return Err(e.context(format!("{what}: fatal error (not retried)")));
                    }
                    if attempt >= self.max_retries {
                        return Err(e.context(format!(
                            "{what}: transient error persisted after {} retries",
                            self.max_retries
                        )));
                    }
                    let ms = self.backoff_ms(attempt);
                    attempt += 1;
                    eprintln!(
                        "[retry] {what}: transient failure, retry {attempt}/{} in {ms} ms ({e:#})",
                        self.max_retries
                    );
                    if ms > 0 {
                        std::thread::sleep(std::time::Duration::from_millis(ms));
                    }
                }
            }
        }
    }
}

/// What to inject at a scheduled chunk index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// `next_chunk` fails with a transient error **before** reading the
    /// inner source (a retry re-delivers the exact suppressed chunk)
    TransientRead,
    /// the chunk is delivered with its last row missing — downstream
    /// contiguity/row-count checks must fail fast, never retry
    Truncated,
    /// the chunk is delivered with row 0's features poisoned to NaN
    NanRow,
}

/// Deterministic schedule of injected faults, keyed by within-sweep
/// chunk index. Explicit sites compose with a seeded pseudo-random
/// transient pattern (a pure hash of `(seed, chunk index)`, so the
/// schedule is identical on every sweep and every run).
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    sites: BTreeMap<usize, (FaultKind, u32)>,
    seeded: Option<(u64, u32, u32)>, // (seed, rate per mille, fail times)
    fatal_sweep: Option<usize>,      // kill the whole run on this sweep (0-based)
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Inject `kind` at chunk `idx`, failing `times` consecutive
    /// attempts per sweep (only meaningful for `TransientRead`).
    pub fn at(mut self, idx: usize, kind: FaultKind, times: u32) -> FaultPlan {
        self.sites.insert(idx, (kind, times.max(1)));
        self
    }

    /// Seeded transient faults: chunk `i` faults iff
    /// `fnv(seed, i) % 1000 < rate_per_mille`, failing `times` attempts.
    pub fn seeded_transient(mut self, seed: u64, rate_per_mille: u32, times: u32) -> FaultPlan {
        self.seeded = Some((seed, rate_per_mille.min(1000), times.max(1)));
        self
    }

    /// Simulate a process kill: every read during sweep `sweep` (0-based,
    /// counted across [`DataSource::reset`] calls and **not** replayed)
    /// fails with a fatal error. In a streamed fit the center pass is
    /// sweep 0, the RHS build sweep 1, and each CG iteration one more
    /// sweep — so killing sweep `k + 2` dies mid-CG, which is exactly
    /// what the checkpoint/resume contract has to survive.
    pub fn kill_at_sweep(mut self, sweep: usize) -> FaultPlan {
        self.fatal_sweep = Some(sweep);
        self
    }

    fn site(&self, idx: usize) -> Option<(FaultKind, u32)> {
        if let Some(&s) = self.sites.get(&idx) {
            return Some(s);
        }
        if let Some((seed, rate, times)) = self.seeded {
            let h = fingerprint_u64s(seed, &[idx as u64]);
            if (h % 1000) < rate as u64 {
                return Some((FaultKind::TransientRead, times));
            }
        }
        None
    }
}

/// Deterministic fault-injection wrapper: presents the inner source
/// unchanged except at scheduled chunk indices. Per-sweep attempt
/// counters reset on [`DataSource::reset`], so every sweep replays the
/// same fault schedule.
pub struct FaultySource {
    inner: Box<dyn DataSource>,
    plan: FaultPlan,
    idx: usize,
    remaining: BTreeMap<usize, u32>,
    injected: usize,
    /// completed `reset()` calls — the sweep counter for `kill_at_sweep`
    /// (deliberately *not* cleared by reset)
    sweeps_started: usize,
}

impl FaultySource {
    pub fn new(inner: Box<dyn DataSource>, plan: FaultPlan) -> FaultySource {
        FaultySource {
            inner,
            plan,
            idx: 0,
            remaining: BTreeMap::new(),
            injected: 0,
            sweeps_started: 0,
        }
    }

    /// Total faults injected since construction (across sweeps) — lets
    /// tests and benches assert the schedule actually fired.
    pub fn injected(&self) -> usize {
        self.injected
    }
}

impl DataSource for FaultySource {
    fn d(&self) -> usize {
        self.inner.d()
    }

    fn len_hint(&self) -> Option<usize> {
        self.inner.len_hint()
    }

    fn reset(&mut self) -> Result<()> {
        self.idx = 0;
        self.remaining.clear();
        self.sweeps_started += 1;
        self.inner.reset()
    }

    fn next_chunk(&mut self) -> Result<Option<Chunk>> {
        if let Some(kill) = self.plan.fatal_sweep {
            // sweeps_started is 1-based after the first reset()
            if self.sweeps_started == kill + 1 {
                self.injected += 1;
                return Err(FaultError::fatal(format!(
                    "injected process kill during sweep {kill}"
                )));
            }
        }
        let i = self.idx;
        if let Some((kind, times)) = self.plan.site(i) {
            match kind {
                FaultKind::TransientRead => {
                    let rem = self.remaining.entry(i).or_insert(times);
                    if *rem > 0 {
                        *rem -= 1;
                        self.injected += 1;
                        // fail BEFORE the inner read: the suppressed chunk
                        // is re-delivered verbatim on retry
                        return Err(FaultError::transient(format!(
                            "injected read fault at chunk {i}"
                        )));
                    }
                }
                FaultKind::Truncated => {
                    let chunk = self.inner.next_chunk()?;
                    self.idx += 1;
                    self.injected += 1;
                    return Ok(chunk.map(|c| {
                        let keep = c.rows().saturating_sub(1);
                        Chunk {
                            start: c.start,
                            x: c.x.slice_rows(0, keep),
                            y: c.y[..keep].to_vec(),
                            labels: c.labels.map(|l| l[..keep].to_vec()),
                        }
                    }));
                }
                FaultKind::NanRow => {
                    let mut chunk = self.inner.next_chunk()?;
                    self.idx += 1;
                    self.injected += 1;
                    if let Some(c) = &mut chunk {
                        if c.rows() > 0 {
                            // dtype-preserving poison (NaN rounds to NaN)
                            c.x.fill_row(0, f64::NAN);
                        }
                    }
                    return Ok(chunk);
                }
            }
        }
        let chunk = self.inner.next_chunk()?;
        self.idx += 1;
        Ok(chunk)
    }

    fn chunk_rows(&self) -> usize {
        self.inner.chunk_rows()
    }

    fn n_classes(&self) -> usize {
        self.inner.n_classes()
    }

    fn name(&self) -> &str {
        self.inner.name()
    }

    fn skipped_rows(&self) -> usize {
        self.inner.skipped_rows()
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over raw u64 words, chained from `seed` — the checkpoint
/// fingerprint primitive (deterministic across runs and platforms).
pub fn fingerprint_u64s(seed: u64, words: &[u64]) -> u64 {
    let mut h = FNV_OFFSET ^ seed;
    for &w in words {
        for byte in w.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// FNV-1a over the exact bit patterns of `vals` (bitwise-sensitive:
/// any ULP change to the data changes the fingerprint).
pub fn fingerprint_f64s(seed: u64, vals: &[f64]) -> u64 {
    let mut h = FNV_OFFSET ^ seed;
    for v in vals {
        for byte in v.to_bits().to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// FNV-1a over a string (kernel names etc. in checkpoint identity).
pub fn fingerprint_str(seed: u64, s: &str) -> u64 {
    let mut h = FNV_OFFSET ^ seed;
    for byte in s.bytes() {
        h ^= byte as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::source::{collect, MemSource};
    use crate::data::synth;
    use crate::util::rng::Rng;

    fn toy(n: usize) -> crate::data::dataset::Dataset {
        synth::smooth_regression(&mut Rng::new(5), n, 4, 0.05)
    }

    fn fast() -> RetryPolicy {
        RetryPolicy {
            max_retries: 4,
            base_backoff_ms: 0,
        }
    }

    #[test]
    fn classify_sees_through_context_layers() {
        let e = FaultError::transient("disk hiccup").context("reading chunk 3");
        assert_eq!(classify(&e), ErrorClass::Transient);
        let e = FaultError::fatal("bad magic").context("opening shard");
        assert_eq!(classify(&e), ErrorClass::Fatal);
    }

    #[test]
    fn classify_io_kinds() {
        let interrupted =
            anyhow::Error::new(std::io::Error::new(std::io::ErrorKind::Interrupted, "sig"));
        assert_eq!(classify(&interrupted), ErrorClass::Transient);
        let missing =
            anyhow::Error::new(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        assert_eq!(classify(&missing), ErrorClass::Fatal);
        assert_eq!(classify(&anyhow::anyhow!("plain")), ErrorClass::Fatal);
    }

    #[test]
    fn retry_recovers_after_transient_failures() {
        let mut calls = 0;
        let got = fast()
            .run("op", || {
                calls += 1;
                if calls < 3 {
                    Err(FaultError::transient("flaky"))
                } else {
                    Ok(42)
                }
            })
            .unwrap();
        assert_eq!(got, 42);
        assert_eq!(calls, 3);
    }

    #[test]
    fn retry_fails_fast_on_fatal() {
        let mut calls = 0;
        let err = fast()
            .run("op", || -> Result<()> {
                calls += 1;
                Err(FaultError::fatal("corrupt"))
            })
            .unwrap_err();
        assert_eq!(calls, 1, "fatal errors must not be retried");
        assert!(format!("{err:#}").contains("not retried"), "{err:#}");
    }

    #[test]
    fn retry_exhausts_budget_with_context() {
        let policy = RetryPolicy {
            max_retries: 2,
            base_backoff_ms: 0,
        };
        let mut calls = 0;
        let err = policy
            .run("read", || -> Result<()> {
                calls += 1;
                Err(FaultError::transient("still down"))
            })
            .unwrap_err();
        assert_eq!(calls, 3); // 1 attempt + 2 retries
        assert!(format!("{err:#}").contains("after 2 retries"), "{err:#}");
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let p = RetryPolicy {
            max_retries: 20,
            base_backoff_ms: 5,
        };
        assert_eq!(p.backoff_ms(0), 5);
        assert_eq!(p.backoff_ms(1), 10);
        assert_eq!(p.backoff_ms(2), 20);
        assert_eq!(p.backoff_ms(19), 1000); // capped
    }

    #[test]
    fn faulty_source_is_transparent_under_retry() {
        // faults at chunks 0 and 2, each failing twice; the retried
        // stream must be byte-identical to the clean one
        let data = toy(100);
        let plan = FaultPlan::new()
            .at(0, FaultKind::TransientRead, 2)
            .at(2, FaultKind::TransientRead, 2);
        let mut src = FaultySource::new(Box::new(MemSource::new(data.clone(), 17)), plan);
        let policy = fast();
        for sweep in 0..2 {
            src.reset().unwrap();
            let mut y = Vec::new();
            let mut xdata = Vec::new();
            while let Some(c) = policy.run("next_chunk", || src.next_chunk()).unwrap() {
                assert_eq!(c.start, y.len(), "sweep {sweep} contiguity");
                c.x.extend_f64(&mut xdata);
                y.extend_from_slice(&c.y);
            }
            assert_eq!(xdata, data.x.data, "sweep {sweep}");
            assert_eq!(y, data.y, "sweep {sweep}");
        }
        // 2 sites x 2 fails x 2 sweeps (counters reset per sweep)
        assert_eq!(src.injected(), 8);
    }

    #[test]
    fn faulty_source_without_retry_surfaces_transient_error() {
        let plan = FaultPlan::new().at(1, FaultKind::TransientRead, 1);
        let mut src = FaultySource::new(Box::new(MemSource::new(toy(60), 20)), plan);
        let err = collect(&mut src).unwrap_err();
        assert_eq!(classify(&err), ErrorClass::Transient);
    }

    #[test]
    fn truncated_chunk_breaks_contiguity() {
        let plan = FaultPlan::new().at(1, FaultKind::Truncated, 1);
        let mut src = FaultySource::new(Box::new(MemSource::new(toy(60), 20)), plan);
        let err = collect(&mut src).unwrap_err();
        // truncation is a data corruption: fatal, never retried
        assert_eq!(classify(&err), ErrorClass::Fatal);
    }

    #[test]
    fn nan_row_injection_poisons_one_row() {
        let plan = FaultPlan::new().at(0, FaultKind::NanRow, 1);
        let mut src = FaultySource::new(Box::new(MemSource::new(toy(40), 40)), plan);
        src.reset().unwrap();
        let c = src.next_chunk().unwrap().unwrap();
        let mut row = vec![0.0f64; c.x.cols()];
        c.x.row_f64_into(0, &mut row);
        assert!(row.iter().all(|v| v.is_nan()));
        c.x.row_f64_into(1, &mut row);
        assert!(row.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn faults_preserve_chunk_dtype() {
        use crate::linalg::mat32::Dtype;
        let plan = FaultPlan::new()
            .at(0, FaultKind::NanRow, 1)
            .at(1, FaultKind::Truncated, 1);
        let mut src = FaultySource::new(
            Box::new(MemSource::with_dtype(toy(40), 20, Dtype::F32)),
            plan,
        );
        src.reset().unwrap();
        let c = src.next_chunk().unwrap().unwrap();
        assert_eq!(c.dtype(), Dtype::F32, "poisoned chunk keeps f32 storage");
        assert!(!c.x.row_is_finite(0));
        assert!(c.x.row_is_finite(1));
        let t = src.next_chunk().unwrap().unwrap();
        assert_eq!(t.dtype(), Dtype::F32, "truncated chunk keeps f32 storage");
        assert_eq!(t.rows(), 19);
    }

    #[test]
    fn kill_at_sweep_fires_once_then_clears() {
        let plan = FaultPlan::new().kill_at_sweep(1);
        let mut src = FaultySource::new(Box::new(MemSource::new(toy(60), 20)), plan);
        collect(&mut src).expect("sweep 0 must be clean");
        let err = collect(&mut src).unwrap_err();
        assert_eq!(classify(&err), ErrorClass::Fatal, "kill is fatal: {err:#}");
        // the "restarted process" sweeps clean again
        collect(&mut src).expect("sweep 2 must be clean");
    }

    #[test]
    fn seeded_schedule_is_deterministic() {
        let plan = FaultPlan::new().seeded_transient(7, 300, 1);
        let a: Vec<usize> = (0..50).filter(|&i| plan.site(i).is_some()).collect();
        let b: Vec<usize> = (0..50).filter(|&i| plan.site(i).is_some()).collect();
        assert_eq!(a, b);
        assert!(!a.is_empty(), "rate 30% over 50 chunks should fire");
        assert!(a.len() < 50, "rate 30% must not fire everywhere");
    }

    #[test]
    fn fingerprints_are_bit_sensitive() {
        let a = fingerprint_f64s(0, &[1.0, 2.0, 3.0]);
        let b = fingerprint_f64s(0, &[1.0, 2.0, f64::from_bits(3.0f64.to_bits() + 1)]);
        assert_eq!(a, fingerprint_f64s(0, &[1.0, 2.0, 3.0]));
        assert_ne!(a, b);
        assert_ne!(fingerprint_f64s(1, &[1.0]), fingerprint_f64s(2, &[1.0]));
        assert_ne!(fingerprint_str(0, "gauss"), fingerprint_str(0, "linear"));
    }
}

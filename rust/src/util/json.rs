//! Minimal JSON parser + writer (substrate — no `serde`/`serde_json` in the
//! offline environment; see DESIGN.md §3).
//!
//! Parses the full JSON grammar into a [`Value`] tree; covers everything the
//! library needs: the artifact manifest, experiment configs and report files.
//! Writing is deliberately simple (no pretty-print options beyond an indent).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as f64 (JSON has only one number type);
/// object keys are ordered (BTreeMap) so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Value {
    // -- typed accessors -------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["key"]` convenience; Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    // -- constructors ----------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(x: f64) -> Value {
        Value::Num(x)
    }
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }
    pub fn arr(xs: Vec<Value>) -> Value {
        Value::Arr(xs)
    }

    // -- writer ------------------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, depth: usize, pretty: bool) {
        let pad = |out: &mut String, d: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..d {
                    out.push(' ');
                }
            }
        };
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    v.write(out, depth + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, depth);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, depth + 1, pretty);
                }
                if !o.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a complete JSON document.
pub fn parse(input: &str) -> Result<Value, JsonError> {
    let mut p = Parser {
        b: input.as_bytes(),
        i: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.i,
        }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character '{}'", c as char))),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    match self.peek().ok_or_else(|| self.err("bad escape"))? {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (no surrogate pairing) — enough for our files
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        c => return Err(self.err(&format!("bad escape '\\{}'", c as char))),
                    }
                    self.i += 1;
                }
                _ => {
                    // consume one UTF-8 code point
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Value::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("c"));
        assert_eq!(v.get("d"), &Value::Null);
        assert_eq!(v.get("missing"), &Value::Null);
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{'a': 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"block":1024,"entries":[{"b":64,"file":"x.hlo.txt","shape":[64,8]}],"ok":true}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn escaping_roundtrip() {
        let v = Value::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn usize_accessor() {
        assert_eq!(parse("7").unwrap().as_usize(), Some(7));
        assert_eq!(parse("7.5").unwrap().as_usize(), None);
        assert_eq!(parse("-1").unwrap().as_usize(), None);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let p = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(s) = std::fs::read_to_string(p) {
            let v = parse(&s).unwrap();
            assert!(v.get("entries").as_arr().unwrap().len() > 10);
        }
    }
}

//! Foundation substrates built from scratch for the offline environment
//! (DESIGN.md §3): PRNG, JSON, timing, property-test harness, worker
//! pool, serving wire format.
pub mod fault;
pub mod json;
pub mod pool;
pub mod ptest;
pub mod rng;
pub mod timer;
pub mod wire;

//! Foundation substrates built from scratch for the offline environment
//! (DESIGN.md §3): PRNG, JSON, timing, property-test harness.
pub mod json;
pub mod ptest;
pub mod rng;
pub mod timer;

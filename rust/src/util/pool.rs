//! Shared persistent worker pool (substrate — `rayon` is unavailable in
//! the offline environment; see DESIGN.md §3 and §Perf).
//!
//! PR 1 buried a channel-fed pool inside `runtime/engine.rs`, usable only
//! by the blocked matvec. This module extracts it as a general primitive
//! so the coordinator's setup-path linear algebra (blocked Cholesky
//! trailing updates, SYRK, tiled K_MM panels) can fan out over the same
//! threads as the per-iteration applies.
//!
//! The design is a **scoped task pool**: threads are spawned once
//! ([`WorkerPool::new`]) and live until the pool is dropped; work arrives
//! as boxed closures over a shared channel. [`WorkerPool::run_scoped`]
//! accepts tasks that *borrow* caller state (`'env` lifetime, like
//! `std::thread::scope`) and blocks until every task has finished, which
//! is what makes the borrow sound — see the safety note there. Per-thread
//! scratch (e.g. the matvec `TileScratch`) lives in thread-locals owned by
//! the call sites, so a 20-iteration fit still allocates worker scratch
//! once, not per apply.
//!
//! Determinism contract: `run_scoped` imposes no ordering between tasks.
//! Callers that partition *output rows* disjointly with a fixed internal
//! loop order stay bitwise equal to their serial runs; callers that
//! reduce per-job partials (the plan apply) sum them in job order, which
//! makes repeated pooled runs bitwise deterministic and serial-equal up
//! to FP regrouping. Both properties are tested at their call sites.

use anyhow::{anyhow, Result};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};

/// A boxed unit of work as it travels over the channel.
type Task = Box<dyn FnOnce() + Send + 'static>;

/// Tracks one `run_scoped` call: outstanding task count plus the first
/// panic payload (re-thrown on the caller thread).
struct ScopeState {
    state: Mutex<(usize, Option<Box<dyn std::any::Any + Send>>)>,
    done: Condvar,
}

/// Persistent channel-fed worker pool: threads spawned once, fed boxed
/// tasks over a shared `Mutex<Receiver>`. Dropping the pool closes the
/// channel and joins the threads.
pub struct WorkerPool {
    tx: Option<mpsc::Sender<Task>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    workers: usize,
}

impl WorkerPool {
    /// Spawn `workers` (≥1) threads named `name`.
    pub fn new(name: &str, workers: usize) -> Result<WorkerPool> {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let rx = Arc::clone(&rx);
            let handle = std::thread::Builder::new()
                .name(name.into())
                .spawn(move || loop {
                    // hold the lock only while dequeueing
                    let task = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    let Ok(task) = task else { break };
                    task();
                })
                .map_err(|e| anyhow!("spawning {name} worker: {e}"))?;
            handles.push(handle);
        }
        Ok(WorkerPool {
            tx: Some(tx),
            handles,
            workers,
        })
    }

    /// Thread count the pool was built with.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `tasks` on the pool and block until all of them finish. Tasks
    /// may borrow caller state (`'env`), exactly like `std::thread::scope`
    /// closures. If a task panics, the panic is re-thrown here after the
    /// remaining tasks have drained (no worker thread dies).
    ///
    /// Must not be called from inside a pool task (a task blocking on
    /// tasks behind it in the same queue can deadlock); all call sites in
    /// this crate fan out from the coordinator thread only.
    pub fn run_scoped<'env>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        if tasks.is_empty() {
            return;
        }
        let scope = Arc::new(ScopeState {
            state: Mutex::new((tasks.len(), None)),
            done: Condvar::new(),
        });
        let tx = self.tx.as_ref().expect("pool sender alive while pool exists");
        for task in tasks {
            // SAFETY: the task's borrows live for 'env; this function does
            // not return until the completion barrier below has observed
            // every task finished (the wrapper decrements even on panic),
            // so no task can outlive the borrows it captured.
            let task: Task = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Task>(task)
            };
            let scope = Arc::clone(&scope);
            let wrapped: Task = Box::new(move || {
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task));
                let mut g = scope.state.lock().unwrap();
                if let Err(payload) = result {
                    if g.1.is_none() {
                        g.1 = Some(payload);
                    }
                }
                g.0 -= 1;
                if g.0 == 0 {
                    scope.done.notify_all();
                }
            });
            tx.send(wrapped).expect("worker pool disconnected");
        }
        let mut g = scope.state.lock().unwrap();
        while g.0 > 0 {
            g = scope.done.wait(g).unwrap();
        }
        if let Some(payload) = g.1.take() {
            drop(g);
            std::panic::resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take(); // closes the channel; workers exit their recv loop
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Fan `tasks` out over `pool` when it has more than one worker; run them
/// inline (in order) otherwise. The shared serial/parallel entry point for
/// the blocked linear-algebra and kernel-panel routines.
pub fn fan_out<'env>(pool: Option<&WorkerPool>, tasks: Vec<Box<dyn FnOnce() + Send + 'env>>) {
    match pool {
        Some(p) if p.workers() > 1 => p.run_scoped(tasks),
        _ => {
            for t in tasks {
                t();
            }
        }
    }
}

/// Split `n` items into at most `parts` contiguous ranges of near-equal
/// size — the chunking used by the pooled routines whose per-item cost is
/// uniform, so serial and pooled runs partition work identically.
pub fn chunk_ranges(n: usize, parts: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let per = n.div_ceil(parts);
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0;
    while lo < n {
        let hi = (lo + per).min(n);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

/// Split `n` items into at most `parts` contiguous ranges of near-equal
/// *total weight* under `weight(i)`. The chunking for triangular
/// workloads (SYRK trailing updates, upper-triangle K_MM panels), where
/// item `i` costs ~`n - i` and equal-count chunks would hand the first
/// worker several times the work of the last. Deterministic in its
/// inputs; ranges always cover [0, n) exactly.
pub fn chunk_ranges_weighted(
    n: usize,
    parts: usize,
    weight: impl Fn(usize) -> u64,
) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    if parts == 1 {
        return vec![(0, n)];
    }
    let total: u64 = (0..n).map(&weight).sum();
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0usize;
    let mut cum = 0u64;
    for k in 0..parts {
        if lo >= n {
            break;
        }
        // cumulative-weight boundary for the end of part k; the last
        // part's boundary is the full total, so coverage is exact
        let target = if k + 1 == parts {
            total
        } else {
            total * (k as u64 + 1) / parts as u64
        };
        let mut hi = lo;
        while hi < n && (hi == lo || cum < target) {
            cum += weight(hi);
            hi += 1;
        }
        out.push((lo, hi));
        lo = hi;
    }
    if let Some(last) = out.last_mut() {
        last.1 = n; // absorb any rounding remainder into the final range
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_borrowing_tasks_to_completion() {
        let pool = WorkerPool::new("test-pool", 4).unwrap();
        let mut out = vec![0usize; 64];
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
            .chunks_mut(7)
            .enumerate()
            .map(|(i, chunk)| {
                let f: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    for (k, v) in chunk.iter_mut().enumerate() {
                        *v = i * 100 + k;
                    }
                });
                f
            })
            .collect();
        pool.run_scoped(tasks);
        for (j, &v) in out.iter().enumerate() {
            assert_eq!(v, (j / 7) * 100 + j % 7);
        }
    }

    #[test]
    fn pool_survives_many_scopes() {
        let pool = WorkerPool::new("test-pool", 3).unwrap();
        let counter = AtomicUsize::new(0);
        for _ in 0..20 {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..5)
                .map(|_| {
                    let c = &counter;
                    let f: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                        c.fetch_add(1, Ordering::SeqCst);
                    });
                    f
                })
                .collect();
            pool.run_scoped(tasks);
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn task_panic_propagates_and_pool_stays_usable() {
        let pool = WorkerPool::new("test-pool", 2).unwrap();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_scoped(vec![Box::new(|| panic!("task boom")) as Box<dyn FnOnce() + Send>]);
        }));
        assert!(caught.is_err(), "panic must propagate to the caller");
        // workers caught the unwind; the pool still executes new tasks
        let ok = AtomicUsize::new(0);
        pool.run_scoped(vec![Box::new(|| {
            ok.fetch_add(1, Ordering::SeqCst);
        }) as Box<dyn FnOnce() + Send + '_>]);
        assert_eq!(ok.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn fan_out_inline_without_pool() {
        let mut sum = 0usize;
        {
            let s = &mut sum;
            fan_out(
                None,
                vec![Box::new(move || {
                    *s = 42;
                }) as Box<dyn FnOnce() + Send + '_>],
            );
        }
        assert_eq!(sum, 42);
    }

    #[test]
    fn weighted_chunks_cover_and_balance_triangular_load() {
        for n in [1usize, 2, 7, 33, 256] {
            for parts in [1usize, 2, 4, 8] {
                let w = |i: usize| (n - i) as u64;
                let ranges = chunk_ranges_weighted(n, parts, w);
                // exact coverage, in order, non-empty
                let mut expect = 0;
                for &(lo, hi) in &ranges {
                    assert_eq!(lo, expect);
                    assert!(hi > lo);
                    expect = hi;
                }
                assert_eq!(expect, n, "n={n} parts={parts}");
                // triangular weights: no chunk should carry more than
                // ~2x the ideal share (equal-count splitting gives the
                // first chunk up to parts× the last)
                if n >= 4 * parts {
                    let total: u64 = (0..n).map(w).sum();
                    let ideal = total / ranges.len() as u64;
                    for &(lo, hi) in &ranges {
                        let got: u64 = (lo..hi).map(w).sum();
                        assert!(
                            got <= 2 * ideal + w(lo),
                            "n={n} parts={parts} range {lo}..{hi} weight {got} vs ideal {ideal}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn chunk_ranges_cover_exactly() {
        for n in [0usize, 1, 2, 5, 17, 64] {
            for parts in [1usize, 2, 3, 8, 100] {
                let ranges = chunk_ranges(n, parts);
                let mut expect = 0;
                for &(lo, hi) in &ranges {
                    assert_eq!(lo, expect);
                    assert!(hi > lo);
                    expect = hi;
                }
                assert_eq!(expect, n, "n={n} parts={parts}");
                assert!(ranges.len() <= parts.max(1));
            }
        }
    }
}

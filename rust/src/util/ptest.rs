//! Tiny property-testing harness (substrate — `proptest` is unavailable in
//! the offline environment; see DESIGN.md §3).
//!
//! `check` runs a property over many seeded random cases and reports the
//! failing seed so a failure reproduces exactly:
//!
//! ```
//! use falkon::util::ptest::{check, Gen};
//! check("sum commutes", 100, |g| {
//!     let (a, b) = (g.f64_in(-10.0, 10.0), g.f64_in(-10.0, 10.0));
//!     assert!((a + b - (b + a)).abs() < 1e-12);
//! });
//! ```

use super::rng::Rng;

/// Per-case generator handed to properties.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.rng.below(hi - lo)
    }
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range(lo, hi)
    }
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        self.rng.normals(n)
    }
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Run `cases` random cases of `prop`. Panics (with the case number and
/// seed baked into the message) on the first failing case.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    check_seeded(name, cases, 0xFA1C0, prop)
}

pub fn check_seeded(
    name: &str,
    cases: usize,
    seed: u64,
    prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe,
) {
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen {
                rng: Rng::new(case_seed),
                case,
            };
            prop(&mut g);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (seed {case_seed:#x}): {msg}\n\
                 reproduce with check_seeded(\"{name}\", 1, {case_seed:#x}, ...)"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("abs is non-negative", 50, |g| {
            let x = g.f64_in(-5.0, 5.0);
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        check("always fails", 10, |_| panic!("boom"));
    }

    #[test]
    fn gen_ranges() {
        check("usize_in respects bounds", 100, |g| {
            let x = g.usize_in(3, 10);
            assert!((3..10).contains(&x));
        });
    }
}

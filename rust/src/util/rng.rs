//! Deterministic pseudo-random numbers (substrate — no `rand` crate in the
//! offline environment; see DESIGN.md §3).
//!
//! The generator is xoshiro256++ seeded through SplitMix64, which is the
//! standard, well-tested construction. Distributions cover exactly what the
//! library needs: uniforms, gaussians (Box–Muller), integer ranges, shuffles,
//! subset sampling and categorical sampling for leverage-score selection.

/// xoshiro256++ PRNG with SplitMix64 seeding. Deterministic across runs and
/// platforms for a given seed — every experiment records its seed.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second gaussian from Box–Muller
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        let s = [
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Lemire multiplicative reduction.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0)
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * th.sin());
        r * th.cos()
    }

    /// Vector of standard normals.
    pub fn normals(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// k distinct indices drawn uniformly from [0, n) (partial Fisher–Yates).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose({k}) from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// One draw from an unnormalized categorical distribution.
    /// O(n); use [`CategoricalSampler`] for repeated draws.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// O(log n)-per-draw categorical sampler over fixed weights (cumulative
/// binary search) — used for leverage-score center sampling where M draws
/// are taken from an n-element distribution.
pub struct CategoricalSampler {
    cum: Vec<f64>,
}

impl CategoricalSampler {
    pub fn new(weights: &[f64]) -> Self {
        assert!(!weights.is_empty());
        let mut cum = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for &w in weights {
            assert!(w >= 0.0 && w.is_finite(), "bad weight {w}");
            acc += w;
            cum.push(acc);
        }
        assert!(acc > 0.0, "all-zero weights");
        CategoricalSampler { cum }
    }

    pub fn total(&self) -> f64 {
        *self.cum.last().unwrap()
    }

    pub fn draw(&self, rng: &mut Rng) -> usize {
        let t = rng.f64() * self.total();
        match self.cum.binary_search_by(|p| p.partial_cmp(&t).unwrap()) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
        .min(self.cum.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_uniformish() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn choose_distinct_and_in_range() {
        let mut r = Rng::new(6);
        let k = r.choose(100, 30);
        assert_eq!(k.len(), 30);
        let mut s = k.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 30);
        assert!(k.iter().all(|&i| i < 100));
    }

    #[test]
    fn choose_all() {
        let mut r = Rng::new(8);
        let mut k = r.choose(5, 5);
        k.sort_unstable();
        assert_eq!(k, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn categorical_sampler_matches_weights() {
        let mut r = Rng::new(9);
        let w = [1.0, 0.0, 3.0, 6.0];
        let s = CategoricalSampler::new(&w);
        let mut counts = [0usize; 4];
        for _ in 0..100_000 {
            counts[s.draw(&mut r)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!((counts[0] as f64 / 1e5 - 0.1).abs() < 0.01);
        assert!((counts[3] as f64 / 1e5 - 0.6).abs() < 0.01);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(11);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}

//! Wall-clock timing helpers shared by the coordinator's metrics and the
//! bench harness.

use std::time::Instant;

/// Simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_s())
}

/// Accumulates named time buckets — used to break the fit down into
/// centers / precond / cg-matvec / cg-other for the §Perf analysis.
#[derive(Debug, Default, Clone)]
pub struct Phases {
    entries: Vec<(String, f64)>,
}

impl Phases {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, name: &str, secs: f64) {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == name) {
            e.1 += secs;
        } else {
            self.entries.push((name.to_string(), secs));
        }
    }

    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let (out, s) = timed(f);
        self.add(name, s);
        out
    }

    pub fn get(&self, name: &str) -> f64 {
        self.entries
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.entries.iter().map(|(_, s)| s).sum()
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        for (name, secs) in &self.entries {
            s.push_str(&format!("{name:>16}: {secs:8.3}s\n"));
        }
        s.push_str(&format!("{:>16}: {:8.3}s\n", "total", self.total()));
        s
    }

    pub fn entries(&self) -> &[(String, f64)] {
        &self.entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_s() >= 0.004);
        assert!(t.elapsed_ms() >= 4.0);
    }

    #[test]
    fn phases_accumulate() {
        let mut p = Phases::new();
        p.add("a", 1.0);
        p.add("a", 2.0);
        p.add("b", 0.5);
        assert_eq!(p.get("a"), 3.0);
        assert_eq!(p.total(), 3.5);
        assert!(p.report().contains("a"));
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 42);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}

//! Little-endian wire substrate for the network serving protocol
//! (`serve/net.rs`): a bounds-checked frame reader and a frame builder.
//! Std-only (DESIGN.md §3) — the offline counterpart of `byteorder`.
//! Every read is length-checked and returns a typed error, never a
//! panic: frames arrive from untrusted sockets and the serving path is
//! covered by the CI panic audit.

use anyhow::{anyhow, Result};

/// Bounds-checked sequential reader over one received frame body.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| {
                anyhow!(
                    "truncated frame: wanted {n} bytes at offset {} of {}",
                    self.pos,
                    self.buf.len()
                )
            })?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([
            s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],
        ]))
    }

    /// f64 transported as raw IEEE-754 bits — predictions cross the wire
    /// bitwise-exactly, which is what lets the serving tests pin
    /// network answers to `model.predict` with `==`.
    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// `count` consecutive f64s.
    pub fn f64s(&mut self, count: usize) -> Result<Vec<f64>> {
        // length sanity before allocating: a hostile count must not OOM
        let remaining = self.buf.len() - self.pos;
        count
            .checked_mul(8)
            .filter(|&b| b <= remaining)
            .ok_or_else(|| anyhow!("frame claims {count} f64s but holds {remaining} bytes"))?;
        let mut v = Vec::with_capacity(count);
        for _ in 0..count {
            v.push(self.f64()?);
        }
        Ok(v)
    }

    /// u32 length-prefixed UTF-8 string.
    pub fn str_u32(&mut self) -> Result<&'a str> {
        let len = self.u32()? as usize;
        std::str::from_utf8(self.take(len)?).map_err(|e| anyhow!("non-UTF-8 string field: {e}"))
    }

    /// Trailing bytes after the last field are a framing error — they
    /// mean reader and writer disagree about the layout.
    pub fn done(&self) -> Result<()> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(anyhow!(
                "{} trailing bytes after the last frame field",
                self.buf.len() - self.pos
            ))
        }
    }
}

/// Builder for one frame body (the length prefix is written by the
/// transport when the frame is sent, not stored here).
#[derive(Default)]
pub struct Writer {
    bytes: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer::default()
    }

    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.bytes.push(v);
        self
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.bytes.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.bytes.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f64(&mut self, v: f64) -> &mut Self {
        self.u64(v.to_bits())
    }

    pub fn f64s(&mut self, vs: &[f64]) -> &mut Self {
        for &v in vs {
            self.f64(v);
        }
        self
    }

    /// u32 length-prefixed UTF-8 string (lengths ≥ 4 GiB are a caller
    /// bug surfaced as a typed error by the transport's frame cap).
    pub fn str_u32(&mut self, s: &str) -> &mut Self {
        self.u32(s.len() as u32);
        self.bytes.extend_from_slice(s.as_bytes());
        self
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.bytes
    }

    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_field_types() {
        let mut w = Writer::new();
        w.u8(7)
            .u32(0xDEAD_BEEF)
            .u64(u64::MAX - 1)
            .f64(-0.0)
            .f64s(&[1.5, f64::NEG_INFINITY, f64::NAN])
            .str_u32("café");
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        // bitwise transport: -0.0 and NaN survive exactly
        assert_eq!(r.f64().unwrap().to_bits(), (-0.0f64).to_bits());
        let v = r.f64s(3).unwrap();
        assert_eq!(v[0], 1.5);
        assert_eq!(v[1], f64::NEG_INFINITY);
        assert!(v[2].is_nan());
        assert_eq!(r.str_u32().unwrap(), "café");
        r.done().unwrap();
    }

    #[test]
    fn truncated_reads_error_without_panicking() {
        let bytes = vec![1u8, 2, 3];
        let mut r = Reader::new(&bytes);
        assert!(r.u32().is_err());
        let mut r = Reader::new(&bytes);
        assert!(r.f64s(10).is_err());
        let mut r = Reader::new(&[5, 0, 0, 0, b'a']);
        assert!(r.str_u32().is_err(), "string length past the buffer");
    }

    #[test]
    fn hostile_f64_count_rejected_before_allocating() {
        let bytes = vec![0u8; 16];
        let mut r = Reader::new(&bytes);
        assert!(r.f64s(usize::MAX / 4).is_err());
    }

    #[test]
    fn trailing_bytes_are_a_framing_error() {
        let mut w = Writer::new();
        w.u8(1).u8(2);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        r.u8().unwrap();
        assert!(r.done().is_err());
        r.u8().unwrap();
        r.done().unwrap();
    }

    #[test]
    fn invalid_utf8_is_a_typed_error() {
        let mut w = Writer::new();
        w.u32(2);
        let mut bytes = w.into_bytes();
        bytes.extend_from_slice(&[0xFF, 0xFE]);
        let mut r = Reader::new(&bytes);
        assert!(r.str_u32().is_err());
    }
}

//! Fault-tolerance acceptance tests (pure-Rust engine): the contract is
//! that a streamed fit under injected *transient* faults is
//! **bitwise identical** to the fault-free fit (retries re-deliver the
//! suppressed chunk verbatim), a fit killed mid-CG resumes from its
//! checkpoint sidecar and reproduces the uninterrupted model, and a
//! degenerate (non-PD) K_MM walks the jitter → eig degradation ladder
//! instead of aborting — every recovery recorded in the [`FitReport`].

use falkon::data::shard::{self, ShardSource};
use falkon::data::source::{collect, Chunk, DataSource, MemSource};
use falkon::data::{synth, Dataset, NanPolicy, SanitizeSource};
use falkon::falkon::{fit_source, setup_precond, CheckpointSpec, FalkonConfig, FitReport};
use falkon::linalg::mat::Mat;
use falkon::runtime::{Engine, EngineOptions};
use falkon::util::fault::{FaultKind, FaultPlan, FaultySource, RetryPolicy};
use falkon::util::rng::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn tmp(tag: &str, ext: &str) -> String {
    std::env::temp_dir()
        .join(format!("falkon_ft_{tag}_{}.{ext}", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn cfg(m: usize, t: usize) -> FalkonConfig {
    FalkonConfig {
        sigma: 2.0,
        lam: 1e-4,
        m,
        t,
        seed: 11,
        ..Default::default()
    }
}

/// Rust engine with zero backoff so retry-heavy tests don't sleep.
fn eng() -> Engine {
    Engine::rust_with(EngineOptions {
        retry: RetryPolicy {
            max_retries: 4,
            base_backoff_ms: 0,
        },
        ..Default::default()
    })
}

/// Forwards to a [`FaultySource`] while mirroring its injection counter
/// into a shared cell — `fit_source` consumes the boxed source, so the
/// test could not ask it afterwards how many faults actually fired.
struct CountingFaults {
    inner: FaultySource,
    injected: Arc<AtomicUsize>,
}

impl DataSource for CountingFaults {
    fn d(&self) -> usize {
        self.inner.d()
    }

    fn len_hint(&self) -> Option<usize> {
        self.inner.len_hint()
    }

    fn reset(&mut self) -> anyhow::Result<()> {
        self.inner.reset()
    }

    fn next_chunk(&mut self) -> anyhow::Result<Option<Chunk>> {
        let r = self.inner.next_chunk();
        self.injected.store(self.inner.injected(), Ordering::Relaxed);
        r
    }

    fn chunk_rows(&self) -> usize {
        self.inner.chunk_rows()
    }

    fn n_classes(&self) -> usize {
        self.inner.n_classes()
    }

    fn name(&self) -> &str {
        self.inner.name()
    }

    fn skipped_rows(&self) -> usize {
        self.inner.skipped_rows()
    }
}

#[test]
fn transient_read_faults_do_not_change_the_fit() {
    // explicit + seeded transient faults on every sweep; bounded retry
    // must re-deliver each suppressed chunk verbatim, so the fitted
    // model is bitwise identical to the fault-free one
    let n = 2000;
    let mut rng = Rng::new(21);
    let data = synth::smooth_regression(&mut rng, n, 5, 0.05);
    let path = tmp("transient", "shard");
    shard::write_dataset(&path, &data).unwrap();
    let e = eng();
    let config = cfg(48, 10);

    let clean = fit_source(&e, Box::new(ShardSource::open(&path, 250).unwrap()), &config).unwrap();

    let plan = FaultPlan::new()
        .at(0, FaultKind::TransientRead, 2)
        .at(3, FaultKind::TransientRead, 4)
        .seeded_transient(0xFA11, 150, 1);
    let injected = Arc::new(AtomicUsize::new(0));
    let faulty = CountingFaults {
        inner: FaultySource::new(Box::new(ShardSource::open(&path, 250).unwrap()), plan),
        injected: injected.clone(),
    };
    let fitted = fit_source(&e, Box::new(faulty), &config).unwrap();

    assert!(injected.load(Ordering::Relaxed) > 0, "no faults fired");
    assert_eq!(fitted.centers.data, clean.centers.data);
    assert_eq!(fitted.alpha, clean.alpha);
    assert!(fitted.report.is_clean(), "{:?}", fitted.report.lines());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn retry_exhaustion_surfaces_a_typed_error() {
    let mut rng = Rng::new(22);
    let data = synth::smooth_regression(&mut rng, 600, 4, 0.05);
    let e = Engine::rust_with(EngineOptions {
        retry: RetryPolicy {
            max_retries: 2,
            base_backoff_ms: 0,
        },
        ..Default::default()
    });
    // more consecutive failures at chunk 0 than the policy tolerates
    let plan = FaultPlan::new().at(0, FaultKind::TransientRead, 8);
    let src = FaultySource::new(Box::new(MemSource::new(data, 100)), plan);
    let err = fit_source(&e, Box::new(src), &cfg(24, 6)).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("transient error persisted after 2 retries"),
        "{msg}"
    );
}

#[test]
fn killed_fit_resumes_from_checkpoint_bitwise() {
    let n = 1600;
    let mut rng = Rng::new(23);
    let data = synth::smooth_regression(&mut rng, n, 4, 0.05);
    let path = tmp("kill", "shard");
    shard::write_dataset(&path, &data).unwrap();
    let e = eng();
    let config = cfg(40, 12);

    let reference =
        fit_source(&e, Box::new(ShardSource::open(&path, 200).unwrap()), &config).unwrap();

    // run 1: checkpoint every iteration, kill the process mid-CG
    // (center pass = sweep 0, rhs = sweep 1, CG iter i = sweep i+1)
    let ck = tmp("kill_ck", "json");
    let _ = std::fs::remove_file(&ck);
    let mut config_ck = config.clone();
    config_ck.checkpoint = Some(CheckpointSpec::new(&ck, 1, false));
    let plan = FaultPlan::new().kill_at_sweep(5);
    let src = FaultySource::new(Box::new(ShardSource::open(&path, 200).unwrap()), plan);
    let err = fit_source(&e, Box::new(src), &config_ck).unwrap_err();
    assert!(format!("{err:#}").contains("injected process kill"), "{err:#}");
    assert!(
        std::path::Path::new(&ck).exists(),
        "no sidecar survived the kill"
    );

    // run 2: clean source, resume from the sidecar — the spliced
    // trajectory must reproduce the uninterrupted model bit for bit
    let mut config_rs = config.clone();
    config_rs.checkpoint = Some(CheckpointSpec::new(&ck, 1, true));
    let resumed = fit_source(
        &e,
        Box::new(ShardSource::open(&path, 200).unwrap()),
        &config_rs,
    )
    .unwrap();
    assert!(
        resumed
            .report
            .lines()
            .iter()
            .any(|l| l.contains("resumed from checkpoint")),
        "{:?}",
        resumed.report.lines()
    );
    assert_eq!(resumed.alpha, reference.alpha);
    assert_eq!(resumed.cg_iters, reference.cg_iters);
    assert!(
        !std::path::Path::new(&ck).exists(),
        "sidecar not cleaned up after a completed fit"
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn indefinite_kmm_escalates_jitter_rungs() {
    // one mildly negative eigenvalue: the base ε ridge fails, a couple
    // of ×100 escalations fix it — recorded, not fatal
    let e = Engine::rust();
    let m = 6;
    let mut kmm = Mat::eye(m);
    kmm[(m - 1, m - 1)] = -1e-4;
    let config = FalkonConfig {
        m,
        lam: 1e-3,
        ..Default::default()
    };
    let mut report = FitReport::default();
    let (t, a, q) = setup_precond(&e, &kmm, &config, &mut report).unwrap();
    assert_eq!(t.rows, m);
    assert_eq!(a.rows, m);
    assert!(q.is_none(), "jitter success must stay on the Chol route");
    assert!(
        report.lines().iter().any(|l| l.contains("jitter escalation")),
        "{:?}",
        report.lines()
    );
}

#[test]
fn hopeless_cholesky_falls_back_to_eig() {
    // a -1e6 eigenvalue is beyond every jitter rung: the ladder must
    // drop to the rank-revealing eig preconditioner and record why
    let e = Engine::rust();
    let m = 6;
    let mut kmm = Mat::eye(m);
    kmm[(m - 1, m - 1)] = -1e6;
    let config = FalkonConfig {
        m,
        lam: 1e-3,
        ..Default::default()
    };
    let mut report = FitReport::default();
    let (t, a, q) = setup_precond(&e, &kmm, &config, &mut report).unwrap();
    let q = q.expect("eig fallback installs Q");
    assert_eq!(q.rows, m);
    assert_eq!(t.rows, a.rows);
    assert!(t.rows < m, "negative eigenvalue must be truncated");
    assert!(
        report.lines().iter().any(|l| l.contains("fell back to eig")),
        "{:?}",
        report.lines()
    );
}

#[test]
fn nan_rows_are_skipped_counted_and_reported() {
    // NaN-poisoned rows under `--nan-policy skip`: the sanitized stream
    // must fit exactly like the same stream with those rows absent
    let n = 1000;
    let d = 4;
    let mut rng = Rng::new(24);
    let data = synth::smooth_regression(&mut rng, n, d, 0.05);
    let e = eng();
    let config = cfg(32, 8);

    // oracle: the stream minus the two rows the plan poisons below
    // (row 0 of chunks 0 and 2 = global rows 0 and 500)
    let mut kept_x = Vec::new();
    let mut kept_y = Vec::new();
    for i in 0..n {
        if i != 0 && i != 500 {
            kept_x.extend_from_slice(data.x.row(i));
            kept_y.push(data.y[i]);
        }
    }
    let kept = Dataset::new_regression("kept", Mat::from_vec(n - 2, d, kept_x), kept_y);
    let oracle_src = SanitizeSource::new(Box::new(MemSource::new(kept, 250)), NanPolicy::Skip);
    let oracle = fit_source(&e, Box::new(oracle_src), &config).unwrap();

    let plan = FaultPlan::new()
        .at(0, FaultKind::NanRow, 1)
        .at(2, FaultKind::NanRow, 1);
    let poisoned = FaultySource::new(Box::new(MemSource::new(data.clone(), 250)), plan);
    let sanitized = SanitizeSource::new(Box::new(poisoned), NanPolicy::Skip);
    let model = fit_source(&e, Box::new(sanitized), &config).unwrap();

    assert!(
        model.report.lines().iter().any(|l| l.contains("non-finite")),
        "{:?}",
        model.report.lines()
    );
    assert_eq!(model.centers.data, oracle.centers.data);
    assert_eq!(model.alpha, oracle.alpha);
}

#[test]
fn nan_rows_fail_fast_by_default_with_row_index() {
    let mut rng = Rng::new(25);
    let data = synth::smooth_regression(&mut rng, 400, 3, 0.05);
    let e = eng();
    let plan = FaultPlan::new().at(1, FaultKind::NanRow, 1);
    let poisoned = FaultySource::new(Box::new(MemSource::new(data, 100)), plan);
    let sanitized = SanitizeSource::new(Box::new(poisoned), NanPolicy::FailFast);
    let err = fit_source(&e, Box::new(sanitized), &cfg(24, 6)).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("non-finite value in row 100"), "{msg}");
    assert!(msg.contains("nan-policy skip"), "{msg}");
    // data corruption is fatal: the retry layer must not have retried it
    assert!(msg.contains("not retried"), "{msg}");
}

#[test]
fn truncated_chunks_are_caught_not_retried() {
    // a short chunk breaks stream contiguity: downstream row accounting
    // must fail loudly rather than fit on silently missing rows
    let mut rng = Rng::new(26);
    let data = synth::smooth_regression(&mut rng, 300, 3, 0.05);
    let plan = FaultPlan::new().at(0, FaultKind::Truncated, 1);
    let mut src = FaultySource::new(Box::new(MemSource::new(data, 100)), plan);
    let err = collect(&mut src).unwrap_err();
    assert!(format!("{err:#}").contains("contiguous"), "{err:#}");
}

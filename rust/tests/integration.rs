//! End-to-end integration over the real AOT artifacts: the XLA (PJRT)
//! engine must agree with the pure-Rust f64 reference on every op and on
//! whole fits. Requires `make artifacts` (tests self-skip with a notice if
//! the manifest is missing).

use falkon::data::synth;
use falkon::falkon::{fit, fit_multiclass, FalkonConfig};
use falkon::kernels::Kernel;
use falkon::linalg::mat::Mat;
use falkon::linalg::vec_ops::rel_diff;
use falkon::metrics;
use falkon::runtime::{Engine, EngineOptions, Impl, Registry};
use falkon::util::rng::Rng;

fn engines() -> Option<(Engine, Engine)> {
    match Engine::xla_default() {
        Ok(x) => Some((x, Engine::rust())),
        Err(e) => {
            eprintln!("SKIP (artifacts not built): {e}");
            None
        }
    }
}

#[test]
fn registry_loads_and_is_complete() {
    let Ok(reg) = Registry::load_default() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    for kern in [Kernel::Gaussian, Kernel::Linear] {
        let ms = reg.usable_ms(kern, 90);
        assert!(
            ms.contains(&256) && ms.contains(&1024),
            "{kern:?} usable Ms {ms:?}"
        );
    }
    // laplacian is compiled for small d only
    assert!(!reg.usable_ms(Kernel::Laplacian, 8).is_empty());
}

#[test]
fn xla_ops_match_rust_ops() {
    let Some((xla, rust)) = engines() else { return };
    let mut rng = Rng::new(11);
    let n = 200;
    for (kern, d, sigma) in [
        (Kernel::Gaussian, 7, 1.4),
        (Kernel::Linear, 12, 1.0),
        (Kernel::Laplacian, 5, 2.0),
    ] {
        let x = Mat::from_vec(n, d, rng.normals(n * d));
        let c = x.select_rows(&rng.choose(n, 32));
        // kmm
        let k1 = xla.kmm(kern, &c, sigma).unwrap();
        let k2 = rust.kmm(kern, &c, sigma).unwrap();
        assert!(k1.max_abs_diff(&k2) < 1e-4, "{kern:?} kmm");
        // kernel_block
        let b1 = xla.kernel_block(kern, &x, &c, sigma).unwrap();
        let b2 = rust.kernel_block(kern, &x, &c, sigma).unwrap();
        assert!(b1.max_abs_diff(&b2) < 1e-4, "{kern:?} block");
        // matvec plan (rhs and iteration paths)
        let u = rng.normals(32);
        let v = rng.normals(n);
        let p1 = xla.matvec_plan(kern, &x, &c, sigma).unwrap();
        let p2 = rust.matvec_plan(kern, &x, &c, sigma).unwrap();
        let w1 = p1.apply(&u, Some(&v)).unwrap();
        let w2 = p2.apply(&u, Some(&v)).unwrap();
        assert!(rel_diff(&w1, &w2) < 5e-4, "{kern:?} matvec: {}", rel_diff(&w1, &w2));
        let w1z = p1.apply(&u, None).unwrap();
        let w2z = p2.apply(&u, None).unwrap();
        assert!(rel_diff(&w1z, &w2z) < 5e-4, "{kern:?} matvec v=0");
        // predictions
        let alpha = rng.normals(32);
        let q1 = xla.predict(kern, &x, &c, &alpha, sigma).unwrap();
        let q2 = rust.predict(kern, &x, &c, &alpha, sigma).unwrap();
        assert!(rel_diff(&q1, &q2) < 5e-4, "{kern:?} predict");
    }
}

#[test]
fn pallas_and_jnp_artifacts_agree() {
    let Ok(reg) = Registry::load_default() else { return };
    let _ = reg;
    let Ok(pal) = Engine::xla(EngineOptions {
        imp: Impl::Pallas,
        workers: 1,
        ..Default::default()
    }) else {
        return;
    };
    let jnp = Engine::xla(EngineOptions {
        imp: Impl::Jnp,
        workers: 1,
        ..Default::default()
    })
    .unwrap();
    let mut rng = Rng::new(12);
    let n = 300;
    let x = Mat::from_vec(n, 10, rng.normals(n * 10));
    let c = x.select_rows(&rng.choose(n, 32));
    let u = rng.normals(32);
    let w1 = pal
        .matvec_plan(Kernel::Gaussian, &x, &c, 1.0)
        .unwrap()
        .apply(&u, None)
        .unwrap();
    let w2 = jnp
        .matvec_plan(Kernel::Gaussian, &x, &c, 1.0)
        .unwrap()
        .apply(&u, None)
        .unwrap();
    assert!(rel_diff(&w1, &w2) < 1e-5, "{}", rel_diff(&w1, &w2));
}

#[test]
fn precond_artifact_matches_rust() {
    let Some((xla, rust)) = engines() else { return };
    let mut rng = Rng::new(13);
    let c = Mat::from_vec(32, 6, rng.normals(192));
    let kmm = rust.kmm(Kernel::Gaussian, &c, 1.2).unwrap();
    let (t1, a1) = xla.precond(&kmm, 1e-3, 1e-6).unwrap();
    let (t2, a2) = rust.precond(&kmm, 1e-3, 1e-6).unwrap();
    // f32 chol vs f64 chol: compare reconstructions, not factors
    let r1 = falkon::linalg::gemm::matmul(&t1.t(), &t1);
    let r2 = falkon::linalg::gemm::matmul(&t2.t(), &t2);
    assert!(r1.max_abs_diff(&r2) < 1e-3);
    let s1 = falkon::linalg::gemm::matmul(&a1.t(), &a1);
    let s2 = falkon::linalg::gemm::matmul(&a2.t(), &a2);
    assert!(s1.max_abs_diff(&s2) < 1e-3);
}

#[test]
fn full_fit_agrees_across_engines() {
    let Some((xla, rust)) = engines() else { return };
    let mut rng = Rng::new(14);
    let data = synth::smooth_regression(&mut rng, 1500, 6, 0.05);
    let (train, test) = data.split(0.2, &mut rng);
    let cfg = FalkonConfig {
        kernel: Kernel::Gaussian,
        sigma: 2.0,
        lam: 1e-4,
        m: 256,
        t: 15,
        seed: 42,
        ..Default::default()
    };
    let mx = fit(&xla, &train.x, &train.y, &cfg).unwrap();
    let mr = fit(&rust, &train.x, &train.y, &cfg).unwrap();
    let px = mx.predict(&xla, &test.x).unwrap();
    let pr = mr.predict(&rust, &test.x).unwrap();
    let ex = metrics::mse(&px, &test.y);
    let er = metrics::mse(&pr, &test.y);
    // same centers (same seed), f32 vs f64 arithmetic — errors must agree
    assert!((ex - er).abs() < 0.05 * er.max(1e-6), "mse {ex} vs {er}");
    assert!(rel_diff(&px, &pr) < 5e-3, "pred rel {}", rel_diff(&px, &pr));
    // and the model must actually have learned
    let var = falkon::linalg::vec_ops::variance(&test.y);
    assert!(ex < 0.5 * var, "mse {ex} vs var {var}");
}

#[test]
fn xla_apply_multi_matches_rust() {
    // the XLA plan's loop-over-columns apply_multi (and the cached
    // padded-center literal it reuses across calls) must agree with the
    // Rust panel-amortized path column-wise
    let Some((xla, rust)) = engines() else { return };
    let mut rng = Rng::new(17);
    let n = 200;
    let x = Mat::from_vec(n, 7, rng.normals(n * 7));
    let c = x.select_rows(&rng.choose(n, 32));
    let k = 4;
    let u = Mat::from_vec(32, k, rng.normals(32 * k));
    let v = Mat::from_vec(n, k, rng.normals(n * k));
    let p1 = xla.matvec_plan(Kernel::Gaussian, &x, &c, 1.4).unwrap();
    let p2 = rust.matvec_plan(Kernel::Gaussian, &x, &c, 1.4).unwrap();
    for vopt in [None, Some(&v)] {
        let w1 = p1.apply_multi(&u, vopt).unwrap();
        let w2 = p2.apply_multi(&u, vopt).unwrap();
        for kc in 0..k {
            let d = rel_diff(&w1.col(kc), &w2.col(kc));
            assert!(d < 5e-4, "col {kc} rel {d}");
        }
    }
    // second plan over the same centers rides the cached literal
    let p3 = xla.matvec_plan(Kernel::Gaussian, &x, &c, 1.4).unwrap();
    let w3 = p3.apply_multi(&u, None).unwrap();
    let w1 = p1.apply_multi(&u, None).unwrap();
    assert!(w3.max_abs_diff(&w1) < 1e-6);
    // multi-output predict path
    let preds_multi = xla.predict_multi(Kernel::Gaussian, &x, &c, &u, 1.4).unwrap();
    for kc in 0..k {
        let want = rust.predict(Kernel::Gaussian, &x, &c, &u.col(kc), 1.4).unwrap();
        assert!(rel_diff(&preds_multi.col(kc), &want) < 5e-4, "predict col {kc}");
    }
}

#[test]
fn multiclass_fit_on_xla() {
    let Some((xla, _)) = engines() else { return };
    let mut rng = Rng::new(15);
    let data = synth::imagenet(&mut rng, 1200);
    let (train, test) = data.split(0.25, &mut rng);
    // raw (un-z-scored) imagenet-analogue distances are ~spread·√(2d)≈224
    let cfg = FalkonConfig {
        kernel: Kernel::Gaussian,
        sigma: 110.0,
        lam: 1e-6,
        m: 256,
        t: 10,
        seed: 1,
        ..Default::default()
    };
    let model = fit_multiclass(&xla, &train, &cfg).unwrap();
    let pred = model.predict_class(&xla, &test.x).unwrap();
    let labels = test.labels.as_ref().unwrap();
    let err = pred.iter().zip(labels).filter(|(p, l)| p != l).count() as f64 / pred.len() as f64;
    assert!(err < 0.5, "c-err {err} (chance 0.9375)");
}

#[test]
fn xla_fit_with_leverage_scores() {
    let Some((xla, _)) = engines() else { return };
    let mut rng = Rng::new(16);
    let data = synth::low_effective_dim(&mut rng, 1000, 10, 3);
    let cfg = FalkonConfig {
        sigma: 1.0,
        lam: 1e-3,
        m: 256,
        t: 12,
        centers: falkon::falkon::Centers::ApproxLeverage { sketch: 256 },
        seed: 2,
        ..Default::default()
    };
    let model = fit(&xla, &data.x, &data.y, &cfg).unwrap();
    let preds = model.predict(&xla, &data.x).unwrap();
    let err = metrics::mse(&preds, &data.y);
    let var = falkon::linalg::vec_ops::variance(&data.y);
    assert!(err < 0.5 * var, "mse {err} var {var}");
}

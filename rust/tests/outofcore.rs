//! End-to-end out-of-core pipeline tests (pure-Rust engine, no
//! artifacts needed): the acceptance contract is that fitting the same
//! synthetic dataset via the in-memory `Dataset` path and via a sharded
//! `DataSource` with a chunk budget **smaller than the dataset** yields
//! predictions agreeing within 1e-8, with only chunk-sized feature
//! blocks resident during the streamed sweeps.

use falkon::data::shard::{self, ShardSource};
use falkon::data::source::{collect, DataSource, MemSource};
use falkon::data::stream_text::{CsvSource, LibsvmSource};
use falkon::data::synth;
use falkon::falkon::{fit, fit_source, prepare_source, solve, Centers, FalkonConfig};
use falkon::linalg::vec_ops::{max_abs_diff, mean};
use falkon::runtime::{Engine, EngineOptions};
use falkon::util::rng::Rng;

fn tmp(tag: &str, ext: &str) -> String {
    std::env::temp_dir()
        .join(format!("falkon_ooc_{tag}_{}.{ext}", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn cfg(m: usize, t: usize) -> FalkonConfig {
    FalkonConfig {
        sigma: 2.0,
        lam: 1e-4,
        m,
        t,
        seed: 11,
        ..Default::default()
    }
}

#[test]
fn sharded_fit_matches_in_memory_fit() {
    // the ISSUE acceptance test: same synthetic dataset, in-memory fit
    // vs a sharded source with a chunk budget far below the dataset
    let n = 3000;
    let mut rng = Rng::new(1);
    let data = synth::smooth_regression(&mut rng, n, 6, 0.05);
    let eng = Engine::rust();
    let config = cfg(64, 12);

    let mem_model = fit(&eng, &data.x, &data.y, &config).unwrap();

    let path = tmp("accept", "shard");
    shard::write_dataset(&path, &data).unwrap();
    let chunk_rows = 500; // 6 chunks per sweep; budget ≪ n
    let src = ShardSource::open(&path, chunk_rows).unwrap();
    assert_eq!(src.len_hint(), Some(n));
    let ooc_model = fit_source(&eng, Box::new(src), &config).unwrap();

    // same seed + known length => identical centers
    assert_eq!(ooc_model.centers.data, mem_model.centers.data);
    // predictions agree within the 1e-8 acceptance budget
    let pm = mem_model.predict(&eng, &data.x).unwrap();
    let po = ooc_model.predict(&eng, &data.x).unwrap();
    let diff = max_abs_diff(&pm, &po);
    assert!(diff < 1e-8, "in-memory vs sharded predictions differ by {diff}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn sharded_fit_keeps_only_chunk_resident() {
    // drive prepare/solve directly so the plan's peak-residency proxy is
    // observable: max resident chunk bytes must stay below the dataset
    let n = 2400;
    let d = 5;
    let mut rng = Rng::new(2);
    let data = synth::smooth_regression(&mut rng, n, d, 0.05);
    let path = tmp("resident", "shard");
    shard::write_dataset(&path, &data).unwrap();
    let chunk_rows = 300;
    let eng = Engine::rust();
    let config = cfg(48, 10);
    let src = ShardSource::open(&path, chunk_rows).unwrap();
    let (mut state, y) = prepare_source(&eng, Box::new(src), &config).unwrap();
    assert_eq!(y, data.y);
    let y_offset = mean(&y);
    let yc: Vec<f64> = y.iter().map(|v| v - y_offset).collect();
    solve(&mut state, &yc, None).unwrap();
    let resident = state.plan.resident_x_bytes().unwrap();
    let full = n * d * 8;
    assert_eq!(resident, chunk_rows * d * 8);
    assert!(
        resident * 4 < full,
        "resident {resident} not well below dataset {full}"
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn sharded_fit_matches_on_pooled_engine() {
    let n = 2200;
    let mut rng = Rng::new(3);
    let data = synth::smooth_regression(&mut rng, n, 4, 0.05);
    let eng = Engine::rust_with(EngineOptions {
        workers: 4,
        ..Default::default()
    });
    let config = cfg(48, 10);
    let mem_model = fit(&eng, &data.x, &data.y, &config).unwrap();
    let path = tmp("pooled", "shard");
    shard::write_dataset(&path, &data).unwrap();
    let src = ShardSource::open(&path, 400).unwrap();
    let ooc_model = fit_source(&eng, Box::new(src), &config).unwrap();
    let pm = mem_model.predict(&eng, &data.x).unwrap();
    let po = ooc_model.predict(&eng, &data.x).unwrap();
    let diff = max_abs_diff(&pm, &po);
    assert!(diff < 1e-8, "pooled diff {diff}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn chunk_budget_does_not_change_the_model() {
    let mut rng = Rng::new(4);
    let data = synth::smooth_regression(&mut rng, 1500, 4, 0.05);
    let eng = Engine::rust();
    let config = cfg(40, 10);
    let path = tmp("budget", "shard");
    shard::write_dataset(&path, &data).unwrap();
    let fit_at = |budget: usize| {
        let src = ShardSource::open(&path, budget).unwrap();
        fit_source(&eng, Box::new(src), &config).unwrap()
    };
    let a = fit_at(97);
    let b = fit_at(1024);
    // serial accumulation is row-ordered regardless of chunk boundaries
    assert_eq!(a.alpha, b.alpha);
    assert_eq!(a.centers.data, b.centers.data);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn streamed_bulk_predict_matches_in_memory() {
    let mut rng = Rng::new(5);
    let data = synth::smooth_regression(&mut rng, 1200, 5, 0.05);
    let eng = Engine::rust();
    let model = fit(&eng, &data.x, &data.y, &cfg(40, 10)).unwrap();
    let want = model.predict(&eng, &data.x).unwrap();
    let path = tmp("bulk", "shard");
    shard::write_dataset(&path, &data).unwrap();
    let mut src = ShardSource::open(&path, 250).unwrap();
    let score = falkon::serve::predict_source(&model, &eng, &mut src).unwrap();
    assert_eq!(score.preds, want);
    assert_eq!(score.targets, data.y);
    assert_eq!(score.max_chunk_bytes, 250 * 5 * 8);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn text_stream_convert_fit_roundtrip() {
    // CSV text -> lazy CsvSource -> shard (stream convert) -> streamed
    // fit; the in-memory loader over the same file is the oracle
    let mut rng = Rng::new(6);
    let n = 600;
    let d = 3;
    let mut csv = String::from("y,f0,f1,f2\n");
    for _ in 0..n {
        let row = rng.normals(d);
        let y = row.iter().sum::<f64>() + 0.1 * rng.normal();
        csv.push_str(&format!("{y},{},{},{}\n", row[0], row[1], row[2]));
    }
    let csv_path = tmp("text", "csv");
    std::fs::write(&csv_path, &csv).unwrap();

    let eager = falkon::data::csv::load_regression(&csv_path, true).unwrap();
    let mut lazy = CsvSource::open(&csv_path, true, 128).unwrap();
    let lazy_collected = collect(&mut lazy).unwrap();
    assert_eq!(lazy_collected.x.data, eager.x.data);
    assert_eq!(lazy_collected.y, eager.y);

    let shard_path = tmp("text", "shard");
    let rows = shard::write_source(&shard_path, &mut lazy).unwrap();
    assert_eq!(rows, n);

    let eng = Engine::rust();
    let config = cfg(32, 8);
    let mem_model = fit(&eng, &eager.x, &eager.y, &config).unwrap();
    let src = ShardSource::open(&shard_path, 128).unwrap();
    let ooc_model = fit_source(&eng, Box::new(src), &config).unwrap();
    let pm = mem_model.predict(&eng, &eager.x).unwrap();
    let po = ooc_model.predict(&eng, &eager.x).unwrap();
    assert!(max_abs_diff(&pm, &po) < 1e-8);

    let _ = std::fs::remove_file(&csv_path);
    let _ = std::fs::remove_file(&shard_path);
}

#[test]
fn libsvm_stream_fits_directly() {
    // a lazy libsvm source plugs straight into fit_source
    let mut rng = Rng::new(7);
    let n = 400;
    let mut text = String::new();
    for _ in 0..n {
        let a = rng.normal();
        let b = rng.normal();
        let y = a - b + 0.05 * rng.normal();
        text.push_str(&format!("{y} 1:{a} 2:{b}\n"));
    }
    let path = tmp("lsvm", "libsvm");
    std::fs::write(&path, &text).unwrap();
    let src = LibsvmSource::open(&path, None, 100).unwrap();
    let eng = Engine::rust();
    let model = fit_source(&eng, Box::new(src), &cfg(32, 8)).unwrap();
    let eager = falkon::data::libsvm::load_regression(&path, None).unwrap();
    let preds = model.predict(&eng, &eager.x).unwrap();
    let err = falkon::metrics::mse(&preds, &eager.y);
    let var = falkon::linalg::vec_ops::variance(&eager.y);
    assert!(err < 0.2 * var, "mse {err} vs var {var}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn mem_source_fit_equals_dataset_fit() {
    // the MemSource backend is the oracle: wrapping the same Dataset
    // must not change the fit at all
    let mut rng = Rng::new(8);
    let data = synth::smooth_regression(&mut rng, 900, 4, 0.05);
    let eng = Engine::rust();
    let config = cfg(40, 10);
    let mem = fit(&eng, &data.x, &data.y, &config).unwrap();
    let ooc = fit_source(&eng, Box::new(MemSource::new(data.clone(), 177)), &config).unwrap();
    assert_eq!(ooc.alpha, mem.alpha);
    assert_eq!(ooc.centers.data, mem.centers.data);
}

#[test]
fn sharded_leverage_fit_matches_in_memory_fit() {
    // leverage-score center selection on a sharded source: the
    // known-length pilot + sampling draws match the in-memory path, so
    // the models agree within the 1e-8 acceptance budget
    let mut rng = Rng::new(33);
    let data = synth::smooth_regression(&mut rng, 1200, 6, 0.05);
    let eng = Engine::rust();
    let config = FalkonConfig {
        centers: Centers::ApproxLeverage { sketch: 96 },
        ..cfg(48, 12)
    };
    let mem_model = fit(&eng, &data.x, &data.y, &config).unwrap();

    let path = tmp("lev", "shard");
    shard::write_dataset(&path, &data).unwrap();
    let src = ShardSource::open(&path, 250).unwrap();
    let ooc_model = fit_source(&eng, Box::new(src), &config).unwrap();

    assert_eq!(ooc_model.centers.data, mem_model.centers.data);
    let pm = mem_model.predict(&eng, &data.x).unwrap();
    let po = ooc_model.predict(&eng, &data.x).unwrap();
    let diff = max_abs_diff(&pm, &po);
    assert!(diff < 1e-8, "leverage in-memory vs sharded differ by {diff}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn streamed_leverage_beats_streamed_uniform_at_small_m() {
    // Thm. 4-5 end-to-end on the streaming path: on the rare-cluster
    // design the rare mass is scattered over sub-clusters that uniform
    // sampling misses at small M, so leverage-score centers reach a
    // lower mean test MSE fitting entirely through a chunked source
    let mut rng = Rng::new(31);
    let data = synth::rare_cluster(&mut rng, 1500, 8, 0.03);
    let (train, test) = data.split(0.2, &mut rng);
    let eng = Engine::rust();

    let mut mses = [Vec::new(), Vec::new()];
    for seed in 41u64..49 {
        let arms = [Centers::Uniform, Centers::ApproxLeverage { sketch: 256 }];
        for (i, centers) in arms.into_iter().enumerate() {
            let config = FalkonConfig {
                sigma: 4.0,
                lam: 1e-4,
                m: 32,
                t: 30,
                centers,
                seed,
                ..Default::default()
            };
            let src = MemSource::new(train.clone(), 200);
            let model = fit_source(&eng, Box::new(src), &config).unwrap();
            let preds = model.predict(&eng, &test.x).unwrap();
            mses[i].push(falkon::metrics::mse(&preds, &test.y));
        }
    }
    let (uni, lev) = (mean(&mses[0]), mean(&mses[1]));
    assert!(
        lev < uni,
        "streamed leverage MSE {lev} not below streamed uniform {uni} at M=32"
    );
}

//! Network serving acceptance tests (pure-Rust engine, loopback TCP).
//!
//! The contract under test (DESIGN.md §Serving): predictions over the
//! wire are **bitwise equal** to direct `model.predict` (f64s travel as
//! raw IEEE-754 bits), concurrent sockets coalesce into shared predict
//! sweeps, a malformed request gets a typed error and fails *alone*
//! (its connection and everyone else's requests keep working), and a
//! hot swap flips the served model atomically — replies come from the
//! old model or the new one, never a mix.

use anyhow::Result;
use falkon::data::{shard, synth};
use falkon::falkon::{fit, fit_multiclass, model_io, FalkonConfig, FalkonModel};
use falkon::runtime::Engine;
use falkon::serve::net::{Client, NetServer};
use falkon::serve::registry::ModelRegistry;
use falkon::serve::ServeConfig;
use falkon::util::rng::Rng;
use std::io::{Read, Write};
use std::sync::Arc;
use std::time::Duration;

const D: usize = 5;

fn tmp(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("falkon_net_{tag}_{}.json", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn fit_cfg(seed: u64) -> FalkonConfig {
    FalkonConfig {
        sigma: 2.0,
        lam: 1e-4,
        m: 48,
        t: 6,
        seed,
        ..Default::default()
    }
}

/// Train a small regression model, save it, and return the **re-loaded**
/// copy so oracle predictions match the served file bit for bit.
fn train_saved(seed: u64, path: &str) -> Result<FalkonModel> {
    let mut rng = Rng::new(seed);
    let data = synth::smooth_regression(&mut rng, 400, D, 0.05);
    let model = fit(&Engine::rust(), &data.x, &data.y, &fit_cfg(seed))?;
    model_io::save(&model, path)?;
    model_io::load(path)
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        max_batch: 64,
        max_wait: Duration::from_millis(4),
        ..Default::default()
    }
}

fn serve_one(path: &str) -> Result<NetServer> {
    let registry = Arc::new(ModelRegistry::new());
    registry.load_file("default", path)?;
    NetServer::start(registry, serve_cfg(), "127.0.0.1:0")
}

#[test]
fn net_predictions_bitwise_match_direct_predict() -> Result<()> {
    let path = tmp("bitwise");
    let model = train_saved(3, &path)?;
    let srv = serve_one(&path)?;
    let addr = srv.addr().to_string();

    let mut rng = Rng::new(77);
    let probe = synth::smooth_regression(&mut rng, 40, D, 0.05);
    let oracle = model.predict(&Engine::rust(), &probe.x)?;

    let mut c = Client::connect(&addr)?;
    for i in 0..8 {
        let got = c.predict_one("default", probe.x.row(i))?;
        assert_eq!(got.to_bits(), oracle[i].to_bits(), "row {i} drifted over the wire");
    }
    let got = c.predict_batch("default", 40, &probe.x.data)?;
    assert_eq!(got.len(), 40);
    for i in 0..40 {
        assert_eq!(got[i].to_bits(), oracle[i].to_bits(), "batch row {i} drifted");
    }

    // unknown model and kind-mismatched op are typed errors, and the
    // connection survives both
    let err = c.predict_one("nope", probe.x.row(0)).unwrap_err();
    assert!(err.to_string().contains("unknown model"), "got: {err:#}");
    let err = c.predict_class("default", 1, probe.x.row(0)).unwrap_err();
    assert!(err.to_string().contains("regression"), "got: {err:#}");
    let after = c.predict_one("default", probe.x.row(0))?;
    assert_eq!(after.to_bits(), oracle[0].to_bits());

    let _ = std::fs::remove_file(&path);
    srv.stop();
    Ok(())
}

#[test]
fn concurrent_net_clients_coalesce_into_shared_batches() -> Result<()> {
    let path = tmp("coalesce");
    let model = train_saved(5, &path)?;
    let srv = serve_one(&path)?;
    let addr = srv.addr().to_string();

    let mut rng = Rng::new(78);
    let probe = synth::smooth_regression(&mut rng, 64, D, 0.05);
    let oracle = model.predict(&Engine::rust(), &probe.x)?;

    const CLIENTS: usize = 16;
    const PER_CLIENT: usize = 8;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|ci| {
                let addr = addr.clone();
                let probe = &probe;
                let oracle = &oracle;
                s.spawn(move || -> Result<()> {
                    let mut c = Client::connect(&addr)?;
                    for i in 0..PER_CLIENT {
                        let row = (ci * PER_CLIENT + i) % probe.x.rows;
                        let got = c.predict_one("default", probe.x.row(row))?;
                        anyhow::ensure!(
                            got.to_bits() == oracle[row].to_bits(),
                            "client {ci} row {row}: batched reply != serial oracle"
                        );
                    }
                    Ok(())
                })
            })
            .collect();
        for h in handles {
            h.join().expect("client thread panicked")?;
        }
        anyhow::Ok(())
    })?;

    let total = (CLIENTS * PER_CLIENT) as u64;
    let stats = srv.stop().remove("default").expect("stats for served model");
    assert_eq!(stats.requests, total, "every request must be counted");
    assert_eq!(stats.rejected, 0);
    assert_eq!(stats.rows, total);
    assert!(
        stats.batches < total,
        "{} batches for {total} concurrent requests: no cross-connection coalescing",
        stats.batches
    );
    assert!(stats.mean_batch > 1.0, "mean batch {}", stats.mean_batch);
    let _ = std::fs::remove_file(&path);
    Ok(())
}

#[test]
fn malformed_net_request_fails_alone() -> Result<()> {
    let path = tmp("malformed");
    let model = train_saved(7, &path)?;
    let srv = serve_one(&path)?;
    let addr = srv.addr().to_string();

    let mut rng = Rng::new(79);
    let probe = synth::smooth_regression(&mut rng, 4, D, 0.05);
    let oracle = model.predict(&Engine::rust(), &probe.x)?;

    // wrong feature count: rejected at the queue boundary with a typed
    // error naming the model dimension; the same connection then serves
    // a well-formed request
    let mut c = Client::connect(&addr)?;
    let err = c.predict_one("default", &[1.0, 2.0]).unwrap_err();
    assert!(err.to_string().contains("model dim"), "got: {err:#}");
    let got = c.predict_one("default", probe.x.row(0))?;
    assert_eq!(got.to_bits(), oracle[0].to_bits());

    let stats = c.stats("default")?;
    assert_eq!(stats.serve.rejected, 1, "the malformed request must be counted");
    assert_eq!(stats.serve.requests, 2, "rejected requests still count as requests");

    // protocol-level garbage (unknown op byte) gets an error frame and
    // the server keeps accepting new connections
    let mut raw = std::net::TcpStream::connect(&addr)?;
    let mut body = vec![99u8];
    body.extend_from_slice(&7u32.to_le_bytes());
    body.extend_from_slice(b"default");
    raw.write_all(&(body.len() as u32).to_le_bytes())?;
    raw.write_all(&body)?;
    let mut len_buf = [0u8; 4];
    raw.read_exact(&mut len_buf)?;
    let mut reply = vec![0u8; u32::from_le_bytes(len_buf) as usize];
    raw.read_exact(&mut reply)?;
    assert_eq!(reply[0], 1, "unknown op must produce an error frame");
    drop(raw);

    let mut c2 = Client::connect(&addr)?;
    let got = c2.predict_one("default", probe.x.row(1))?;
    assert_eq!(got.to_bits(), oracle[1].to_bits());

    let _ = std::fs::remove_file(&path);
    srv.stop();
    Ok(())
}

#[test]
fn hot_swap_over_socket_is_atomic() -> Result<()> {
    let path_a = tmp("swap_a");
    let path_b = tmp("swap_b");
    let model_a = train_saved(11, &path_a)?;
    let model_b = train_saved(13, &path_b)?;
    let srv = serve_one(&path_a)?;
    let addr = srv.addr().to_string();

    let mut rng = Rng::new(80);
    let probe = synth::smooth_regression(&mut rng, 16, D, 0.05);
    let eng = Engine::rust();
    let oracle_a = model_a.predict(&eng, &probe.x)?;
    let oracle_b = model_b.predict(&eng, &probe.x)?;
    assert!(
        oracle_a
            .iter()
            .zip(&oracle_b)
            .any(|(a, b)| a.to_bits() != b.to_bits()),
        "the two checkpoints must actually disagree for this test to mean anything"
    );

    let mut c = Client::connect(&addr)?;
    let before = c.predict_batch("default", 16, &probe.x.data)?;
    for i in 0..16 {
        assert_eq!(before[i].to_bits(), oracle_a[i].to_bits());
    }

    let generation = c.swap("default", &path_b)?;
    assert_eq!(generation, 1, "first swap must move the slot to generation 1");
    let after = c.predict_batch("default", 16, &probe.x.data)?;
    for i in 0..16 {
        assert_eq!(after[i].to_bits(), oracle_b[i].to_bits(), "row {i} still on old model");
    }
    assert_eq!(c.stats("default")?.swaps, 1);

    let _ = std::fs::remove_file(&path_a);
    let _ = std::fs::remove_file(&path_b);
    srv.stop();
    Ok(())
}

#[test]
fn multiclass_over_socket_matches_direct() -> Result<()> {
    let path = tmp("multiclass");
    let mut rng = Rng::new(17);
    let data = synth::blobs(&mut rng, 300, D, 3);
    let eng = Engine::rust();
    let model = fit_multiclass(&eng, &data, &fit_cfg(17))?;
    model_io::save_multiclass(&model, &path)?;
    let model = model_io::load_multiclass(&path)?;

    let srv = serve_one(&path)?;
    let addr = srv.addr().to_string();

    let probe = synth::blobs(&mut rng, 24, D, 3);
    let want_class = model.predict_class(&eng, &probe.x)?;
    let want_scores = model.scores(&eng, &probe.x)?;

    let mut c = Client::connect(&addr)?;
    let got = c.predict_class("default", 24, &probe.x.data)?;
    assert_eq!(got.len(), 24);
    for (i, p) in got.iter().enumerate() {
        assert_eq!(p.class, want_class[i], "row {i} argmax");
        assert_eq!(p.scores.len(), 3);
        for (kc, s) in p.scores.iter().enumerate() {
            assert_eq!(s.to_bits(), want_scores[kc][i].to_bits(), "row {i} class {kc} score");
        }
    }

    // regression ops on a multiclass model are typed errors
    let err = c.predict_one("default", probe.x.row(0)).unwrap_err();
    assert!(err.to_string().contains("multiclass"), "got: {err:#}");

    let _ = std::fs::remove_file(&path);
    srv.stop();
    Ok(())
}

#[test]
fn score_shard_op_scores_a_server_side_file() -> Result<()> {
    let model_path = tmp("shard_model");
    let shard_path = tmp("shard_data");
    let model = train_saved(19, &model_path)?;
    let srv = serve_one(&model_path)?;
    let addr = srv.addr().to_string();

    let mut rng = Rng::new(23);
    let data = synth::smooth_regression(&mut rng, 200, D, 0.05);
    shard::write_dataset(&shard_path, &data)?;
    let preds = model.predict(&Engine::rust(), &data.x)?;
    let want_mse = falkon::metrics::mse(&preds, &data.y);

    let mut c = Client::connect(&addr)?;
    let score = c.score_shard("default", &shard_path, 64)?;
    assert_eq!(score.rows, 200);
    assert_eq!(score.skipped_rows, 0);
    assert!(score.max_chunk_bytes > 0);
    assert!(
        (score.mse - want_mse).abs() <= 1e-8 * want_mse.max(1.0),
        "chunked shard mse {} vs direct {want_mse}",
        score.mse
    );
    assert!((score.rmse - score.mse.sqrt()).abs() < 1e-12);

    let _ = std::fs::remove_file(&model_path);
    let _ = std::fs::remove_file(&shard_path);
    srv.stop();
    Ok(())
}
